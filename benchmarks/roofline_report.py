"""Roofline table from the dry-run JSON records (deliverable g).

Reads experiments/dryrun/<tag>/*.json and prints/writes the per-cell
three-term roofline with bottleneck, useful-compute ratio, and the
roofline fraction. Compare two tags (baseline vs an optimization) with
--compare.
"""
from __future__ import annotations

import argparse
import json
import os
from typing import Dict, List, Optional

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun")


def load(tag: str) -> List[Dict]:
    d = os.path.join(DRYRUN_DIR, tag)
    out = []
    if not os.path.isdir(d):
        return out
    for fn in sorted(os.listdir(d)):
        if fn.endswith(".json"):
            with open(os.path.join(d, fn)) as f:
                out.append(json.load(f))
    return out


def fmt_row(rec: Dict) -> str:
    if "error" in rec:
        return (f"{rec['arch']:24s} {rec['shape']:12s} {rec['mesh']:6s} "
                f"ERROR: {rec['error'][:60]}")
    r = rec["roofline"]
    ma = rec["memory_analysis"]
    return (
        f"{rec['arch']:24s} {rec['shape']:12s} {rec['mesh']:6s} "
        f"{r['compute_s']*1e3:10.1f} {r['memory_s']*1e3:10.1f} "
        f"{r['collective_s']*1e3:10.1f}  {r['bottleneck']:10s} "
        f"{r['useful_ratio']:6.3f} {r['roofline_fraction']:6.3f} "
        f"{ma['peak_bytes_est']/2**30:7.1f}"
    )


HEADER = (
    f"{'arch':24s} {'shape':12s} {'mesh':6s} "
    f"{'comp_ms':>10s} {'mem_ms':>10s} {'coll_ms':>10s}  {'bottleneck':10s} "
    f"{'useful':>6s} {'frac':>6s} {'GiB/dev':>7s}"
)


def run(tag: str = "baseline", compare: Optional[str] = None, mesh: str = "single"):
    recs = [r for r in load(tag) if r.get("mesh") == mesh or mesh == "both"]
    print(f"roofline [{tag}] ({len(recs)} cells, mesh={mesh})")
    print(HEADER)
    for rec in recs:
        print(fmt_row(rec))
    n_err = sum("error" in r for r in recs)
    print(f"cells: {len(recs)}  failures: {n_err}")

    if compare:
        base = {(r["arch"], r["shape"], r["mesh"]): r for r in load(compare)}
        print(f"\ndelta vs [{compare}] (dominant-term change):")
        for rec in recs:
            key = (rec["arch"], rec["shape"], rec["mesh"])
            if key not in base or "error" in rec or "error" in base[key]:
                continue
            b, n = base[key]["roofline"], rec["roofline"]
            dom = b["bottleneck"] + "_s"
            before, after = b[dom], n.get(dom, 0.0)
            if before > 0:
                print(f"  {rec['arch']:24s} {rec['shape']:12s} {dom[:-2]:10s} "
                      f"{before*1e3:9.1f} -> {after*1e3:9.1f} ms "
                      f"({(after/before-1)*100:+.1f}%)  frac "
                      f"{b['roofline_fraction']:.3f} -> {n['roofline_fraction']:.3f}")
    return recs


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--tag", default="baseline")
    ap.add_argument("--compare", default="")
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    a = ap.parse_args()
    run(a.tag, a.compare or None, a.mesh)


if __name__ == "__main__":
    main()

"""Default-vs-tuned tile-plan sweep for the three Pallas kernels.

For each swept launch shape the autotuner's measured search
(:func:`repro.kernels.tuning.search`) times every valid candidate plan —
including the 128-defaults plan, inside the same sweep — so the
``speedup`` column is never a cross-sweep noise artifact and
``tuned >= default`` throughput holds on every row by construction
(exact ties keep the default plan). Winners are persisted into the plan
cache, so this sweep doubles as a cache-warming step: the CI autotune
job uploads the resulting ``experiments/kernel_cache.json`` next to
``BENCH_kernels.json``.

Off-TPU the search runs interpret-mode Pallas (the identical kernel
path, executed on host), so rows exist in CI too; absolute times there
measure the interpreter, the *ordering* is what the artifact asserts.
"""
from __future__ import annotations

import jax

from benchmarks.common import Table
from repro.kernels import tuning


def _sweep(quick: bool):
    """(kernel, dims, dtypes, params) launch shapes to tune."""
    f32 = "float32"
    mm = [(256, 128, 128), (128, 256, 256)] if quick else \
         [(512, 512, 512), (1024, 512, 2048), (2048, 2048, 512),
          (4096, 1024, 1024)]
    work = []
    for M, K, N in mm:
        dims = {"M": M, "K": K, "N": N}
        work.append(("masked_matmul", dims, {"x": f32, "w": f32}, {}))
    # one 2:4 sparse shape (K % m == 0 by construction of the sweep)
    M, K, N = mm[-1]
    work.append(("nm_spmm", {"M": M, "K": K, "N": N},
                 {"x": f32, "v": f32}, {"n": 2, "m": 4}))
    att = [(4, 128, 64)] if quick else [(16, 512, 64), (32, 1024, 128)]
    for BH, S, d in att:
        work.append(("flash_attention",
                     {"BH": BH, "Sq": S, "Sk": S, "d": d},
                     {"q": f32}, {"causal": True}))
    return work


def run(quick: bool = True) -> Table:
    table = Table("kernels", [
        "kernel", "shape", "candidates", "default_s", "tuned_s",
        "speedup", "tiles",
    ])
    interpret = jax.default_backend() != "tpu"
    for kernel, dims, dtypes, params in _sweep(quick):
        entry = tuning.search(kernel, dims, dtypes, params,
                              interpret=interpret,
                              reps=3 if quick else 5)
        tuning.store(entry)
        default_s = entry["measured_s"]["default"]
        best_s = entry["measured_s"]["best"]
        shape = "x".join(str(v) for v in dims.values())
        tiles = ",".join(f"{k}={v}" for k, v in
                         sorted(entry["tiles"].items())) or "(default)"
        table.add(kernel, shape, entry["candidates"],
                  f"{default_s:.4f}", f"{best_s:.4f}",
                  f"{default_s / best_s:.2f}x", tiles)
    table.write()
    return table

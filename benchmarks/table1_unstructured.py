"""Paper Table 1: unstructured-sparsity sweep.

ppl(method) vs ppl(method + DSnoT) vs ppl(method + EBFT) across sparsity
levels, for magnitude / Wanda / SparseGPT initial masks. The paper's
claims validated here (as orderings at miniature scale):

  * EBFT improves every method at every sparsity,
  * EBFT > DSnoT (whose gains fade / reverse at high sparsity),
  * SparseGPT (weight-updating) > Wanda (mask-only) as sparsity grows.
"""
from __future__ import annotations

from repro.core.evaluate import perplexity
from repro.core.masks import prune

from benchmarks import common as C


def run(sparsities=(0.5, 0.6, 0.7, 0.8, 0.9), methods=("magnitude", "wanda", "sparsegpt"),
        epochs: int = 8, quick: bool = False):
    if quick:
        sparsities = (0.5, 0.7, 0.9)
        epochs = 5
    model, dense = C.dense_teacher()
    calib, ev = C.standard_sets(model)
    ppl_dense = perplexity(model, dense, ev)
    t = C.Table("table1_unstructured",
                ["method", "sparsity", "ppl_pruned", "ppl_dsnot", "ppl_ebft", "ppl_dense"])
    print(f"table1: dense ppl {ppl_dense:.2f}")
    for method in methods:
        for s in sparsities:
            masks, pruned = prune(model, dense, calib, method=method, sparsity=s)
            ppl_p = perplexity(model, pruned, ev)
            _, ds = prune(model, dense, calib, method="dsnot", sparsity=s,
                          dsnot_init=method)
            ppl_d = perplexity(model, ds, ev)
            tuned, _, _ = C.run_ebft(model, dense, pruned, masks, calib, epochs)
            ppl_e = perplexity(model, tuned, ev)
            t.add(method, s, f"{ppl_p:.2f}", f"{ppl_d:.2f}", f"{ppl_e:.2f}",
                  f"{ppl_dense:.2f}")
    path = t.write()

    # the paper's headline orderings
    ok = all(float(r[4]) <= float(r[2]) * 1.02 for r in t.rows)
    print(f"table1: EBFT <= pruned on all rows: {ok}  -> {path}")
    return t


if __name__ == "__main__":
    run()

"""Paper Tables 4/5: EBFT vs LoRA under structured (FLAP) sparsity.

The paper's claims: EBFT reaches better ppl than LoRA at ~10x less
fine-tuning cost. Cost here is wall-seconds on the container CPU (the
relative cost is the claim; absolute numbers are hardware-bound).
LoRA trains on the LM objective over a data stream (the paper's
Alpaca-GPT4 analogue = our synthetic corpus iterator); EBFT uses only the
calibration set.
"""
from __future__ import annotations

import time

from repro.core import lora
from repro.core.evaluate import cloze_accuracy, perplexity
from repro.core.masks import prune
from repro.data.tokens import cloze_task, corpus_iterator

from benchmarks import common as C


def run(sparsities=(0.2, 0.35), lora_steps: int = 400, epochs: int = 8,
        quick: bool = False):
    if quick:
        sparsities = (0.25,)
        lora_steps = 150
        epochs = 5
    model, dense = C.dense_teacher()
    calib, ev = C.standard_sets(model)
    corpus = C.shared_corpus(model.cfg.vocab_size)
    ctx, tn, dn = cloze_task(corpus, 96, 64)
    t = C.Table("table4_lora",
                ["sparsity", "ppl_flap", "ppl_lora", "ppl_ebft",
                 "acc_lora", "acc_ebft", "time_lora_s", "time_ebft_s"])
    for s in sparsities:
        masks, pruned = prune(model, dense, calib, method="flap", sparsity=s)
        ppl_f = perplexity(model, pruned, ev)

        t0 = time.time()
        it = corpus_iterator(corpus, batch=8, seq_len=128, seed=11)
        merged = lora.finetune_lora(
            model, pruned, masks, it,
            lora.LoRAConfig(steps=lora_steps, lr=1e-3, rank=8),
        )
        dt_lora = time.time() - t0
        ppl_l = perplexity(model, merged, ev)
        acc_l = cloze_accuracy(model, merged, ctx, tn, dn)

        tuned, _, dt_ebft = C.run_ebft(model, dense, pruned, masks, calib, epochs)
        ppl_e = perplexity(model, tuned, ev)
        acc_e = cloze_accuracy(model, tuned, ctx, tn, dn)

        t.add(s, f"{ppl_f:.2f}", f"{ppl_l:.2f}", f"{ppl_e:.2f}",
              f"{acc_l:.3f}", f"{acc_e:.3f}", f"{dt_lora:.0f}", f"{dt_ebft:.0f}")
    path = t.write()
    print(f"table4 -> {path}")
    return t


if __name__ == "__main__":
    run()

"""Paper Table 3: zero-shot-task generality (synthetic-cloze stand-in).

Accuracy of ranking the true template continuation over a distractor, for
pruned / DSnoT / EBFT models at 60% sparsity — the paper's claim is that
EBFT recovers generality (not just LM ppl) better than DSnoT.
"""
from __future__ import annotations

from repro.core.evaluate import cloze_accuracy, perplexity
from repro.core.masks import prune
from repro.data.tokens import cloze_task

from benchmarks import common as C


def run(sparsity: float = 0.6, methods=("magnitude", "wanda", "sparsegpt"),
        epochs: int = 8, quick: bool = False):
    if quick:
        methods = ("magnitude", "wanda")
        epochs = 5
    model, dense = C.dense_teacher()
    calib, ev = C.standard_sets(model)
    corpus = C.shared_corpus(model.cfg.vocab_size)
    ctx, true_next, distract = cloze_task(corpus, 128, 64)
    acc_dense = cloze_accuracy(model, dense, ctx, true_next, distract)
    t = C.Table("table3_zeroshot",
                ["method", "acc_pruned", "acc_dsnot", "acc_ebft", "acc_dense"])
    for method in methods:
        masks, pruned = prune(model, dense, calib, method=method, sparsity=sparsity)
        a_p = cloze_accuracy(model, pruned, ctx, true_next, distract)
        _, ds = prune(model, dense, calib, method="dsnot", sparsity=sparsity,
                      dsnot_init=method)
        a_d = cloze_accuracy(model, ds, ctx, true_next, distract)
        tuned, _, _ = C.run_ebft(model, dense, pruned, masks, calib, epochs)
        a_e = cloze_accuracy(model, tuned, ctx, true_next, distract)
        t.add(method, f"{a_p:.3f}", f"{a_d:.3f}", f"{a_e:.3f}", f"{acc_dense:.3f}")
    path = t.write()
    print(f"table3 -> {path}")
    return t


if __name__ == "__main__":
    run()

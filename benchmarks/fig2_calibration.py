"""Paper Fig. 2: perplexity vs number of calibration samples.

Claims validated: more samples -> better ppl, saturating; even 8 samples
beat no fine-tuning.
"""
from __future__ import annotations

from repro.core.evaluate import perplexity
from repro.core.masks import prune
from repro.data.tokens import calibration_set

from benchmarks import common as C


def run(sample_counts=(8, 16, 32, 64, 128), sparsity: float = 0.6,
        epochs: int = 8, quick: bool = False):
    if quick:
        sample_counts = (8, 32, 128)
        epochs = 5
    model, dense = C.dense_teacher()
    corpus = C.shared_corpus(model.cfg.vocab_size)
    calib_full, ev = C.standard_sets(model, n_calib=max(sample_counts))
    masks, pruned = prune(model, dense, calib_full, method="wanda", sparsity=sparsity)
    ppl_pruned = perplexity(model, pruned, ev)
    t = C.Table("fig2_calibration", ["n_samples", "ppl_ebft", "ppl_pruned"])
    for n in sample_counts:
        calib = calibration_set(corpus, n, 128)
        tuned, _, _ = C.run_ebft(model, dense, pruned, masks, calib, epochs)
        ppl = perplexity(model, tuned, ev)
        t.add(n, f"{ppl:.2f}", f"{ppl_pruned:.2f}")
    path = t.write()
    mono_ok = float(t.rows[-1][1]) <= float(t.rows[0][1]) * 1.05
    beats_pruned = float(t.rows[0][1]) <= ppl_pruned
    print(f"fig2: saturating-improvement={mono_ok} 8-samples-beat-no-FT={beats_pruned} -> {path}")
    return t


if __name__ == "__main__":
    run()

"""Paper Table 2: semi-structured (N:M) sparsity — 2:4 and 4:8 patterns."""
from __future__ import annotations

from repro.core.evaluate import perplexity
from repro.core.masks import prune

from benchmarks import common as C


def run(patterns=((2, 4), (4, 8)), methods=("magnitude", "wanda", "sparsegpt"),
        epochs: int = 8, quick: bool = False):
    if quick:
        patterns = ((2, 4),)
        epochs = 5
    model, dense = C.dense_teacher()
    calib, ev = C.standard_sets(model)
    t = C.Table("table2_nm",
                ["method", "pattern", "ppl_pruned", "ppl_dsnot", "ppl_ebft"])
    for method in methods:
        for (n, m) in patterns:
            masks, pruned = prune(model, dense, calib, method=method,
                                  sparsity=1 - n / m, pattern=(n, m))
            ppl_p = perplexity(model, pruned, ev)
            _, ds = prune(model, dense, calib, method="dsnot",
                          sparsity=1 - n / m, pattern=(n, m), dsnot_init=method)
            ppl_d = perplexity(model, ds, ev)
            tuned, _, _ = C.run_ebft(model, dense, pruned, masks, calib, epochs)
            ppl_e = perplexity(model, tuned, ev)
            t.add(method, f"{n}:{m}", f"{ppl_p:.2f}", f"{ppl_d:.2f}", f"{ppl_e:.2f}")
    path = t.write()
    ok = all(float(r[4]) <= float(r[2]) * 1.02 for r in t.rows)
    print(f"table2: EBFT <= pruned on all rows: {ok}  -> {path}")
    return t


if __name__ == "__main__":
    run()

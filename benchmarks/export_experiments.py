"""Render the §Dry-run / §Roofline markdown tables for EXPERIMENTS.md from
the dry-run JSON records (so the document is regenerable from artifacts).

    python -m benchmarks.export_experiments [--baseline baseline] [--optimized optimized]
"""
from __future__ import annotations

import argparse
from typing import Dict, List

from benchmarks.roofline_report import load


def _ms(x: float) -> str:
    return f"{x*1e3:.1f}"


def dryrun_table(recs: List[Dict], mesh: str) -> str:
    rows = [r for r in recs if r.get("mesh") == mesh]
    out = [
        "| arch | shape | kind | chips | HBM/dev (GiB) | HLO GFLOPs/dev | coll wire MB/dev | status |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        if "error" in r:
            out.append(f"| {r['arch']} | {r['shape']} | {r['kind']} | {r['chips']} "
                       f"| — | — | — | ERROR |")
            continue
        ma, st = r["memory_analysis"], r["hlo_stats"]
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['kind']} | {r['chips']} "
            f"| {ma['peak_bytes_est']/2**30:.1f} "
            f"| {st['flops']/1e9:.0f} "
            f"| {st['collective_wire']/1e6:.0f} | ok |"
        )
    return "\n".join(out)


def roofline_table(recs: List[Dict], mesh: str = "single") -> str:
    rows = [r for r in recs if r.get("mesh") == mesh and "error" not in r]
    out = [
        "| arch | shape | compute (ms) | memory (ms) | collective (ms) | bottleneck | useful 6ND/HLO | roofline frac |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        t = r["roofline"]
        out.append(
            f"| {r['arch']} | {r['shape']} | {_ms(t['compute_s'])} "
            f"| {_ms(t['memory_s'])} | {_ms(t['collective_s'])} "
            f"| **{t['bottleneck']}** | {t['useful_ratio']:.3f} "
            f"| {t['roofline_fraction']:.3f} |"
        )
    return "\n".join(out)


def compare_table(base: List[Dict], opt: List[Dict]) -> str:
    bidx = {(r["arch"], r["shape"], r["mesh"]): r for r in base if "error" not in r}
    out = [
        "| arch | shape | term | baseline (ms) | optimized (ms) | delta | frac before → after |",
        "|---|---|---|---|---|---|---|",
    ]
    for r in sorted(opt, key=lambda r: (r["arch"], r["shape"])):
        key = (r["arch"], r["shape"], r["mesh"])
        if r["mesh"] != "single" or "error" in r or key not in bidx:
            continue
        b, n = bidx[key]["roofline"], r["roofline"]
        dom = b["bottleneck"] + "_s"
        before, after = b[dom], n[dom]
        if before <= 0:
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {b['bottleneck']} "
            f"| {_ms(before)} | {_ms(after)} | {after/before:.3f}x "
            f"| {b['roofline_fraction']:.3f} → {n['roofline_fraction']:.3f} |"
        )
    return "\n".join(out)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", default="baseline")
    ap.add_argument("--optimized", default="optimized")
    ap.add_argument("--section", default="all",
                    choices=["all", "dryrun", "roofline", "compare"])
    args = ap.parse_args()
    base = load(args.baseline)
    opt = load(args.optimized)
    if args.section in ("all", "dryrun"):
        print("### Dry-run, single pod (16x16 = 256 chips)\n")
        print(dryrun_table(opt or base, "single"))
        print("\n### Dry-run, multi-pod (2x16x16 = 512 chips)\n")
        print(dryrun_table(opt or base, "multi"))
    if args.section in ("all", "roofline"):
        print("\n### Roofline (baseline, paper-faithful distribution)\n")
        print(roofline_table(base))
        print("\n### Roofline (optimized)\n")
        print(roofline_table(opt))
    if args.section in ("all", "compare") and base and opt:
        print("\n### Baseline → optimized (dominant-term deltas)\n")
        print(compare_table(base, opt))


if __name__ == "__main__":
    main()

"""Shared harness for the paper-table benchmarks.

All tables run on the tiny_dense config at miniature scale (DESIGN.md §7:
no Llama weights / C4 in the container), validating the paper's claims as
RELATIVE ORDERINGS on a synthetic corpus. The dense teacher is pretrained
once and cached under experiments/cache/.

EBFT's learning rate is scaled to the tiny model (1e-2 vs the paper's
2e-4 for Llama-7B): block reconstruction needs steps sized to the model's
own training lr (3e-3 here), as the paper sizes theirs to Llama's.
"""
from __future__ import annotations

import os
import time
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import ckpt as CK
from repro.configs import get_config
from repro.core import ebft
from repro.core.evaluate import cloze_accuracy, perplexity
from repro.core.masks import prune
from repro.data.tokens import (
    CorpusConfig, SyntheticCorpus, calibration_set, corpus_iterator, eval_set,
)
from repro.models.model import build
from repro.obs import metrics as OM
from repro.obs import trace as OT
from repro.obs.run import current_run
from repro.optim.optimizers import adamw
from repro.training.train_loop import make_train_step

CACHE = os.path.join(os.path.dirname(__file__), "..", "experiments", "cache")
EBFT_LR = 1e-2
PRETRAIN_STEPS = 300


def bench_spec(**overrides):
    """The benchmark harness's settings as a :class:`RunSpec`.

    Tables write their BENCH_*.json manifest header through this, so the
    artifacts carry the same round-trippable ``run_spec`` section the
    launchers do (repro.launch.api) instead of ad-hoc keys.
    """
    from repro.launch.api import RunSpec

    base = dict(kind="ebft", arch="tiny_dense", lr=EBFT_LR,
                pretrain_steps=PRETRAIN_STEPS, mesh_data=1)
    base.update(overrides)
    return RunSpec(**base)


def dense_teacher(arch: str = "tiny_dense", steps: int = PRETRAIN_STEPS):
    """Pretrained tiny model (cached on disk across benchmark runs)."""
    cfg = get_config(arch)
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    ckdir = os.path.join(CACHE, f"{arch}_{steps}")
    if CK.latest_step(ckdir) == steps:
        params = CK.restore(ckdir, {"params": params})["params"]
        return model, params

    corpus = shared_corpus(cfg.vocab_size)
    opt = adamw(3e-3)
    step = jax.jit(make_train_step(model.loss, opt))
    opt_state = opt.init(params)
    it = corpus_iterator(corpus, batch=32, seq_len=128, seed=1)
    for _ in range(steps):
        params, opt_state, _, _ = step(
            params, opt_state, {"tokens": jnp.asarray(next(it))}, None
        )
    CK.save(ckdir, {"params": params}, step=steps, async_write=False)
    return model, params


_CORPORA: Dict[int, SyntheticCorpus] = {}


def shared_corpus(vocab: int) -> SyntheticCorpus:
    if vocab not in _CORPORA:
        _CORPORA[vocab] = SyntheticCorpus(CorpusConfig(vocab_size=vocab))
    return _CORPORA[vocab]


def standard_sets(model, n_calib: int = 64, seq: int = 128):
    corpus = shared_corpus(model.cfg.vocab_size)
    return (
        calibration_set(corpus, n_calib, seq),
        eval_set(corpus, 16, seq),
    )


def run_ebft(model, dense, pruned, masks, calib, epochs: int = 8,
             fused_epochs: bool = True, prefetch_depth: int = 1):
    ecfg = ebft.EBFTConfig(lr=EBFT_LR, epochs=epochs, microbatch=8, patience=3,
                           fused_epochs=fused_epochs,
                           prefetch_depth=prefetch_depth)
    t0 = time.perf_counter()
    with OT.span("bench/ebft", epochs=epochs, lr=EBFT_LR,
                 fused=fused_epochs, prefetch=prefetch_depth) as sp:
        tuned, reports = ebft.finetune(model, dense, pruned, masks, calib, ecfg)
        sp.fence(tuned)
    elapsed = time.perf_counter() - t0
    OM.histogram("bench/ebft_s").observe(elapsed)
    return tuned, reports, elapsed


# ---------------------------------------------------------------------------
class Table:
    """Collects rows, prints aligned text + writes CSV to experiments/.

    Console output is one sink; when an obs run is active (benchmarks/run.py
    starts one per table) each row is also mirrored into the JSONL event
    stream and the final summary artifact via ``Run.say``.
    """

    def __init__(self, name: str, columns: List[str]):
        self.name = name
        self.columns = columns
        self.rows: List[List] = []

    def add(self, *row):
        self.rows.append(list(row))
        line = "  " + "  ".join(f"{v}" for v in row)
        run = current_run()
        if run is not None:
            run.say(line)
        else:
            print(line, flush=True)

    def write(self, out_dir: Optional[str] = None):
        out_dir = out_dir or os.path.join(
            os.path.dirname(__file__), "..", "experiments", "benchmarks"
        )
        os.makedirs(out_dir, exist_ok=True)
        path = os.path.join(out_dir, f"{self.name}.csv")
        with open(path, "w") as f:
            f.write(",".join(self.columns) + "\n")
            for r in self.rows:
                f.write(",".join(str(v) for v in r) + "\n")
        return path

"""Paper Table 6 / §4.5: weight tuning (EBFT) vs mask tuning.

Both optimize the same Eq.4 block objective on the same calibration set;
mask tuning moves mask positions with frozen weights (STE), EBFT moves
weights with frozen masks. Claim: weight tuning wins at every sparsity.
"""
from __future__ import annotations

from repro.core import ebft, mask_tuning
from repro.core.evaluate import perplexity
from repro.core.masks import prune

from benchmarks import common as C


def run(sparsities=(0.5, 0.6, 0.7, 0.8, 0.9), epochs: int = 8, quick: bool = False):
    if quick:
        sparsities = (0.5, 0.7, 0.9)
        epochs = 5
    model, dense = C.dense_teacher()
    calib, ev = C.standard_sets(model)
    t = C.Table("table6_masktuning",
                ["sparsity", "ppl_pruned", "ppl_mask_tune", "ppl_weight_tune"])
    for s in sparsities:
        masks, pruned = prune(model, dense, calib, method="wanda", sparsity=s)
        ppl_p = perplexity(model, pruned, ev)
        mt, _ = mask_tuning.finetune_masks(
            model, dense, masks, s, calib,
            ebft.EBFTConfig(lr=2e-2, epochs=epochs, microbatch=8, patience=3),
        )
        ppl_m = perplexity(model, mt, ev)
        tuned, _, _ = C.run_ebft(model, dense, pruned, masks, calib, epochs)
        ppl_w = perplexity(model, tuned, ev)
        t.add(s, f"{ppl_p:.2f}", f"{ppl_m:.2f}", f"{ppl_w:.2f}")
    path = t.write()
    wins = sum(float(r[3]) <= float(r[2]) for r in t.rows)
    print(f"table6: weight-tuning wins {wins}/{len(t.rows)} rows -> {path}")
    return t


if __name__ == "__main__":
    run()

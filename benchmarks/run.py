"""Benchmark orchestrator: one function per paper table/figure.

    python -m benchmarks.run            # quick grids (CI-sized)
    python -m benchmarks.run --full     # the paper's full grids
    python -m benchmarks.run --only table1,table6

Each table prints rows as it goes, writes a CSV under
experiments/benchmarks/, and the roofline report (deliverable g) is
appended from the dry-run artifacts if present.
"""
from __future__ import annotations

import argparse
import time

from benchmarks import (
    fig2_calibration, roofline_report, table1_unstructured, table2_nm,
    table3_zeroshot, table4_lora, table6_masktuning,
)

ALL = {
    "table1": lambda quick: table1_unstructured.run(quick=quick),
    "table2": lambda quick: table2_nm.run(quick=quick),
    "table3": lambda quick: table3_zeroshot.run(quick=quick),
    "table4": lambda quick: table4_lora.run(quick=quick),
    "fig2": lambda quick: fig2_calibration.run(quick=quick),
    "table6": lambda quick: table6_masktuning.run(quick=quick),
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="paper-sized grids")
    ap.add_argument("--only", default="", help="comma list of table names")
    args = ap.parse_args()

    names = args.only.split(",") if args.only else list(ALL)
    t_all = time.time()
    for name in names:
        print(f"\n=== {name} {'(full)' if args.full else '(quick)'} ===", flush=True)
        t0 = time.time()
        ALL[name](quick=not args.full)
        print(f"=== {name} done in {time.time()-t0:.0f}s ===")

    print("\n=== roofline (from dry-run artifacts) ===")
    try:
        if roofline_report.load("optimized"):
            roofline_report.run("optimized", compare="baseline")
        else:
            roofline_report.run("baseline")
    except Exception as e:  # noqa: BLE001 — dry-run may not have run yet
        print(f"(skipped: {e})")
    print(f"\nall benchmarks done in {time.time()-t_all:.0f}s")


if __name__ == "__main__":
    main()

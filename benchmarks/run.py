"""Benchmark orchestrator: one function per paper table/figure.

    python -m benchmarks.run            # quick grids (CI-sized)
    python -m benchmarks.run --full     # the paper's full grids
    python -m benchmarks.run --only table1,table6
    python -m benchmarks.run --no-obs   # console/CSV only, no artifacts

Each table prints rows as it goes, writes a CSV under
experiments/benchmarks/, and — unless ``--no-obs`` — runs inside an
observability run that writes ``experiments/benchmarks/BENCH_<name>.json``
(manifest + metrics + trace + the table rows; render with
``python -m repro.obs report``). The roofline report (deliverable g) is
appended from the dry-run artifacts if present.
"""
from __future__ import annotations

import argparse
import os
import time

from benchmarks import (
    bench_kernels, fig2_calibration, roofline_report, table1_unstructured,
    table2_nm, table3_zeroshot, table4_lora, table6_masktuning,
)
from benchmarks.common import bench_spec
from repro.obs.run import start_run

ALL = {
    "table1": lambda quick: table1_unstructured.run(quick=quick),
    "table2": lambda quick: table2_nm.run(quick=quick),
    "table3": lambda quick: table3_zeroshot.run(quick=quick),
    "table4": lambda quick: table4_lora.run(quick=quick),
    "fig2": lambda quick: fig2_calibration.run(quick=quick),
    "table6": lambda quick: table6_masktuning.run(quick=quick),
    "kernels": lambda quick: bench_kernels.run(quick=quick),
}

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments", "benchmarks")


def run_one(name: str, quick: bool, obs: bool) -> float:
    """Run one table under its own obs run; returns elapsed seconds."""
    run = None
    if obs:
        # the RunSpec section makes bench manifests round-trippable the
        # same way the launcher artifacts are (repro.launch.api)
        run = start_run(f"bench_{name}",
                        extra_manifest={**bench_spec().to_manifest(),
                                        "quick": quick, "table": name})
    t0 = time.perf_counter()
    table = ALL[name](quick=quick)
    dt = time.perf_counter() - t0
    if run is not None:
        extra = {"elapsed_s": dt}
        if table is not None and hasattr(table, "rows"):
            extra["table"] = {"name": table.name, "columns": table.columns,
                              "rows": table.rows}
        os.makedirs(OUT_DIR, exist_ok=True)
        run.finish(extra=extra,
                   summary_path=os.path.join(OUT_DIR, f"BENCH_{name}.json"))
    return dt


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="paper-sized grids")
    ap.add_argument("--only", default="", help="comma list of table names")
    ap.add_argument("--no-obs", action="store_true",
                    help="disable observability artifacts")
    args = ap.parse_args()

    names = args.only.split(",") if args.only else list(ALL)
    t_all = time.perf_counter()
    for name in names:
        print(f"\n=== {name} {'(full)' if args.full else '(quick)'} ===", flush=True)
        dt = run_one(name, quick=not args.full, obs=not args.no_obs)
        print(f"=== {name} done in {dt:.0f}s ===")

    print("\n=== roofline (from dry-run artifacts) ===")
    try:
        if roofline_report.load("optimized"):
            roofline_report.run("optimized", compare="baseline")
        else:
            roofline_report.run("baseline")
    except Exception as e:  # noqa: BLE001 — dry-run may not have run yet
        print(f"(skipped: {e})")
    print(f"\nall benchmarks done in {time.perf_counter()-t_all:.0f}s")


if __name__ == "__main__":
    main()

"""Distributed training step + host-side loop.

``make_train_step`` builds the jit-able pure step:

    (params, opt_state, batch[, err_state]) -> (params, opt_state, metrics)

with optional microbatch gradient accumulation (lax.scan over microbatches
— bounds activation memory the same way remat bounds it within a block)
and optional top-k gradient compression with error feedback (the cross-pod
all-reduce payload shrinker; see optim/grad_compress.py).

``Trainer`` is the host loop: data feeding, checkpoint/restart (elastic:
restore reshapes to the current mesh), straggler-tolerant determinism (data
order is a pure function of step), and metric logging. No wall-clock
dependency — it runs identically on CPU and on a pod.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Iterator, Optional

import jax
import jax.numpy as jnp

from repro.obs import metrics as OM
from repro.obs import trace as OT
from repro.optim import grad_compress as GC
from repro.optim.optimizers import Optimizer, apply_updates, clip_by_global_norm


def make_train_step(
    loss_fn: Callable,  # (params, batch) -> (loss, metrics)
    opt: Optimizer,
    *,
    microbatches: int = 1,
    grad_clip: float = 1.0,
    compress_ratio: float = 1.0,
    constrain_microbatch: Optional[Callable] = None,
) -> Callable:
    """Returns train_step(params, opt_state, batch, err_state) ->
    (params, opt_state, metrics, err_state).

    ``constrain_microbatch``: applied to the (microbatches, local, ...)
    reshaped batch. Under pjit the reshape splits the sharded global-batch
    dim in two and GSPMD may move the sharding to the microbatch dim —
    which makes every device all-gather the tokens and redundantly compute
    the whole microbatch. The constraint pins batch sharding to dim 1.
    """

    grad_fn = jax.value_and_grad(lambda p, b: loss_fn(p, b)[0])

    def accumulate(params, batch):
        if microbatches <= 1:
            return grad_fn(params, batch)

        def reshape(x):
            return x.reshape((microbatches, x.shape[0] // microbatches) + x.shape[1:])

        mb = jax.tree.map(reshape, batch)
        if constrain_microbatch is not None:
            mb = constrain_microbatch(mb)

        def body(carry, b):
            loss_acc, g_acc = carry
            loss, g = grad_fn(params, b)
            g_acc = jax.tree.map(
                lambda a, x: a + x.astype(jnp.float32) / microbatches, g_acc, g
            )
            return (loss_acc + loss / microbatches, g_acc), None

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (loss, grads), _ = jax.lax.scan(body, (jnp.zeros((), jnp.float32), zeros), mb)
        return loss, grads

    def train_step(params, opt_state, batch, err_state=None):
        loss, grads = accumulate(params, batch)
        if compress_ratio < 1.0 and err_state is not None:
            grads, err_state = GC.compress(grads, err_state, compress_ratio)
        grads, gnorm = clip_by_global_norm(grads, grad_clip)
        updates, opt_state = opt.update(grads, opt_state, params)
        params = apply_updates(params, updates)
        metrics = {"loss": loss, "grad_norm": gnorm}
        return params, opt_state, metrics, err_state

    return train_step


# ---------------------------------------------------------------------------
@dataclasses.dataclass
class Trainer:
    """Host-side loop with checkpoint/restart and deterministic data order."""

    step_fn: Callable  # jitted train_step
    data_fn: Callable[[int], Dict[str, Any]]  # step -> host batch (determinism!)
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 100
    log_every: int = 10

    def run(self, params, opt_state, start_step: int, num_steps: int, err_state=None):
        from repro.checkpoint import ckpt as CK  # lazy: avoid cycle

        history = []
        step = start_step
        with OT.span("train/run", start=start_step, steps=num_steps):
            for step in range(start_step, start_step + num_steps):
                batch = self.data_fn(step)  # pure function of step: any host
                # can recompute it after a restart — failures just rejoin.
                params, opt_state, metrics, err_state = self.step_fn(
                    params, opt_state, batch, err_state
                )
                OM.counter("train/steps").inc()
                if step % self.log_every == 0:
                    # obs: sync-ok (log_every is the user's sync-cadence knob)
                    loss = float(metrics["loss"])
                    history.append((step, loss))
                    OM.series("train/loss").append(loss, step=step)
                    if "grad_norm" in metrics:
                        OM.series("train/grad_norm").append(
                            float(metrics["grad_norm"]), step=step  # obs: sync-ok
                        )
                if self.ckpt_dir and (step + 1) % self.ckpt_every == 0:
                    with OT.span("train/checkpoint", step=step + 1):
                        CK.save(
                            self.ckpt_dir,
                            {"params": params, "opt_state": opt_state},
                            step=step + 1,
                            async_write=True,
                        )
        if self.ckpt_dir:
            # drain in-flight async writes first: a periodic save of this
            # same step may still be writing its .tmp — racing a second
            # writer against it can leave no visible checkpoint at all
            CK.wait_all()
            if CK.latest_step(self.ckpt_dir) != step + 1:
                CK.save(
                    self.ckpt_dir,
                    {"params": params, "opt_state": opt_state},
                    step=step + 1,
                    async_write=False,
                )
        return params, opt_state, history

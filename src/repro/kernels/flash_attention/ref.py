"""Pure-jnp oracle for the flash attention kernel."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def flash_attention_ref(
    q: jax.Array, k: jax.Array, v: jax.Array, *, causal: bool = True, q_offset: int = 0
) -> jax.Array:
    """Dense softmax attention. q (BH, Sq, d); k/v (BH, Sk, d)."""
    scale = 1.0 / math.sqrt(q.shape[-1])
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32), k.astype(jnp.float32)) * scale
    if causal:
        qp = q_offset + jnp.arange(q.shape[1])[:, None]
        kp = jnp.arange(k.shape[1])[None, :]
        s = jnp.where(qp >= kp, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)

"""jit'd public wrapper for flash attention.

``flash_attention_bshd`` adapts the model-layer layout (B, S, H, hd) with
GQA head-repetition folded in; used by models/layers.attend when
impl="flash" on TPU. Off-TPU the portable chunked-jnp path in
models/layers.py is the equivalent (same online-softmax recurrence).

Observability accounting: 4·BH·Sq·Sk·hd FLOPs (QKᵀ + PV), halved for
causal masking; HBM traffic is q/k/v/out (the whole point of the fused
kernel is that the S×S score matrix never touches HBM).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import tuning
from repro.kernels.flash_attention.flash_attention import flash_attention as _kernel
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.obs import trace as OT
from repro.obs.profile import is_abstract, record_kernel


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def flash_attention(q, k, v, *, causal=True, q_offset=0, interpret=False, **tiles):
    plan_src = None
    if (on_tpu() or interpret) and not tiles:
        # q_offset is deliberately not part of the key: it shifts the
        # causal mask, not the tiling trade-off
        tiles, plan_src = tuning.resolve(
            "flash_attention",
            {"BH": int(q.shape[0]), "Sq": int(q.shape[1]),
             "Sk": int(k.shape[1]), "d": int(q.shape[2])},
            {"q": str(q.dtype)},
            {"causal": bool(causal)},
            interpret=interpret,
        )

    def run():
        if on_tpu() or interpret:
            return _kernel(
                q, k, v, causal=causal, q_offset=q_offset,
                interpret=interpret or not on_tpu(), **tiles,
            )
        return flash_attention_ref(q, k, v, causal=causal, q_offset=q_offset)

    if not OT.enabled() or is_abstract(q, k, v):
        return run()
    BH, Sq, hd = q.shape
    Sk = k.shape[1]
    flops = 4.0 * BH * Sq * Sk * hd * (0.5 if causal else 1.0)
    traffic = sum(a.size * a.dtype.itemsize for a in (q, k, v)) \
        + q.size * q.dtype.itemsize
    attrs = dict(plan=plan_src, **tiles) if plan_src else None
    return record_kernel("kernels/flash_attention", flops, traffic, run,
                         attrs=attrs)


def call(*operands, interpret: bool = False, **params):
    """Uniform kernel entry point (see repro.kernels.dispatch): operands
    are ``(q, k, v)`` in (BH, S, hd) layout; pass ``layout="bshd"`` for
    the model-layer (B, S, H, hd) layout."""
    if params.pop("layout", "bh_s_d") == "bshd":
        return flash_attention_bshd(*operands, interpret=interpret, **params)
    return flash_attention(*operands, interpret=interpret, **params)


def flash_attention_bshd(q, k, v, *, causal=True, q_offset=0, interpret=False):
    """q (B, Sq, H, hd); k/v (B, Sk, H, hd) already GQA-repeated."""
    B, Sq, H, hd = q.shape
    Sk = k.shape[1]
    fold = lambda x, S: x.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    o = flash_attention(
        fold(q, Sq), fold(k, Sk), fold(v, Sk),
        causal=causal, q_offset=q_offset, interpret=interpret,
    )
    return o.reshape(B, H, Sq, hd).transpose(0, 2, 1, 3)

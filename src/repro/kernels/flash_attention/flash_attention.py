"""Blocked (flash) causal attention Pallas TPU kernel.

The 32k-prefill hot spot: materializing (Sq, Sk) scores at 32k² is 4 GiB
per head — far beyond VMEM. This kernel runs the online-softmax recurrence
over (bq, bk) tiles: running max m, normalizer l, and the output
accumulator live in VMEM scratch across the Sk sweep; HBM traffic is
O(S·d) instead of O(S²).

Causality is handled two ways:
  * tiles entirely above the diagonal are *skipped* (no MXU work — the
    grid still visits them, but `pl.when` guards all compute), halving
    effective FLOPs for long sequences;
  * the diagonal tile applies an iota-based mask.

Layout: q (B·H, Sq, d), k/v (B·H, Sk, d) — callers fold batch and (GQA-
repeated) heads into dim 0. Grid: (B·H, Sq/bq, Sk/bk), Sk minormost.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.validation import plan_flash_attention

_NEG = -1e30


def _kernel(
    q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
    *, scale: float, causal: bool, bq: int, bk: int, k_steps: int, q_offset: int,
):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # absolute positions of this tile's queries/keys
    q_pos0 = q_offset + qi * bq       # queries start here in the kv timeline
    k_pos0 = ki * bk

    # skip tiles strictly above the causal diagonal
    run = (not causal) or (q_pos0 + bq - 1 >= k_pos0)
    is_diag = causal and (q_pos0 < k_pos0 + bk - 1)

    @pl.when(run)
    def _compute():
        q = q_ref[0].astype(jnp.float32)          # (bq, d)
        k = k_ref[0].astype(jnp.float32)          # (bk, d)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale

        if causal:
            # mask only needed on (partially) diagonal tiles
            qp = q_pos0 + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            kp = k_pos0 + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(qp >= kp, s, _NEG)

        m_prev = m_ref[...]                        # (bq, 1)
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)                     # (bq, bk)
        corr = jnp.exp(m_prev - m_new)             # (bq, 1)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jnp.dot(
            p.astype(v_ref.dtype), v_ref[0], preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new

    @pl.when(ki == k_steps - 1)
    def _flush():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "bq", "bk", "q_offset", "interpret")
)
def flash_attention(
    q: jax.Array,  # (BH, Sq, d)
    k: jax.Array,  # (BH, Sk, d)
    v: jax.Array,  # (BH, Sk, d)
    *,
    causal: bool = True,
    bq: int = 128,
    bk: int = 128,
    q_offset: int = 0,
    interpret: bool = False,
) -> jax.Array:
    BH, Sq, d = q.shape
    if k.shape[0] != BH or v.shape != k.shape or k.shape[2] != d:
        raise ValueError(
            f"flash_attention: inconsistent operand shapes q={q.shape} "
            f"k={k.shape} v={v.shape}"
        )
    _, Sk, _ = k.shape
    # validates tile divisibility (after clamping) and is the exact plan
    # repro.analysis checks statically
    plan = plan_flash_attention(BH, Sq, Sk, d, bq=bq, bk=bk, q_dtype=q.dtype)
    bq, bk = plan.tiles["bq"], plan.tiles["bk"]
    k_steps = plan.grid[2]
    scale = 1.0 / math.sqrt(d)
    qb, kb, vb = plan.inputs
    (ob,) = plan.outputs

    return pl.pallas_call(
        functools.partial(
            _kernel, scale=scale, causal=causal, bq=bq, bk=bk,
            k_steps=k_steps, q_offset=q_offset,
        ),
        grid=plan.grid,
        in_specs=[
            pl.BlockSpec(qb.shape, qb.index_map),
            pl.BlockSpec(kb.shape, kb.index_map),
            pl.BlockSpec(vb.shape, vb.index_map),
        ],
        out_specs=pl.BlockSpec(ob.shape, ob.index_map),
        out_shape=jax.ShapeDtypeStruct((BH, Sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)

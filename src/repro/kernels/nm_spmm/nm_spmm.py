"""N:M sparse matmul Pallas TPU kernel: out = x @ decompress(vals, idx).

TPU has no sparse tensor cores, so the honest N:M win on TPU is **HBM
bandwidth and footprint** (DESIGN.md §3): a 2:4 weight stores N/M = ½ the
values plus int8 group offsets (2-bit packable), i.e. ~0.56× the bytes of
the dense bf16 weight. This kernel streams the *compressed* representation
HBM→VMEM, decompresses each (bk, bn) weight tile in VMEM with a
compare-and-accumulate (no scatter — TPU-vector friendly), and feeds the
dense tile straight to the MXU.

Layout (produced by sparsity/sparse_params.nm_compress):
    vals (K//m·n, N)   kept values, group-major along K
    idx  (K//m·n, N)   int8 offset of each kept value inside its M-group

Grid: (M/bm, N/bn, K/bk) with the f32 accumulator in VMEM scratch across
the K sweep. The compressed K-tile has bk//m·n rows — contiguous, since
groups follow K order.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.validation import plan_nm_spmm


def _kernel(x_ref, v_ref, i_ref, o_ref, acc_ref, *, n: int, m: int, k_steps: int):
    @pl.when(pl.program_id(2) == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    vals = v_ref[...]                      # (G*n, bn)
    idx = i_ref[...].astype(jnp.int32)     # (G*n, bn)
    G = vals.shape[0] // n
    bn = vals.shape[1]

    # VMEM decompress: dense[g, o, c] = Σ_s vals[g, s, c] · [idx[g, s, c] == o]
    vals_g = vals.reshape(G, n, bn)
    idx_g = idx.reshape(G, n, bn)
    dense = jnp.zeros((G, m, bn), vals.dtype)
    for s in range(n):  # n is tiny (1..4): unrolled compare-accumulate
        onehot = (
            idx_g[:, s, None, :] == jax.lax.broadcasted_iota(jnp.int32, (G, m, bn), 1)
        )
        dense = dense + jnp.where(onehot, vals_g[:, s, None, :], 0)
    w_tile = dense.reshape(G * m, bn)      # (bk, bn)

    acc_ref[...] += jnp.dot(x_ref[...], w_tile, preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("n", "m", "bm", "bk", "bn", "interpret")
)
def nm_spmm(
    x: jax.Array,     # (M, K)
    vals: jax.Array,  # (K//m*n, N)
    idx: jax.Array,   # (K//m*n, N) int8
    *,
    n: int,
    m: int,
    bm: int = 128,
    bk: int = 128,
    bn: int = 128,
    interpret: bool = False,
) -> jax.Array:
    M, K = x.shape
    KC, N = vals.shape
    if KC * m != K * n:
        raise ValueError(
            f"nm_spmm: compressed rows {KC} inconsistent with K={K} under "
            f"{n}:{m} (want K//m*n = {K // m * n})"
        )
    # validates group alignment + tile divisibility (after clamping) and is
    # the exact plan repro.analysis checks statically
    plan = plan_nm_spmm(
        M, K, N, n=n, m=m, bm=bm, bk=bk, bn=bn,
        x_dtype=x.dtype, v_dtype=vals.dtype,
    )
    k_steps = plan.grid[2]
    xb, vb, ib = plan.inputs
    (ob,) = plan.outputs

    return pl.pallas_call(
        functools.partial(_kernel, n=n, m=m, k_steps=k_steps),
        grid=plan.grid,
        in_specs=[
            pl.BlockSpec(xb.shape, xb.index_map),
            pl.BlockSpec(vb.shape, vb.index_map),
            pl.BlockSpec(ib.shape, ib.index_map),
        ],
        out_specs=pl.BlockSpec(ob.shape, ob.index_map),
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        scratch_shapes=[pltpu.VMEM(ob.shape, jnp.float32)],
        interpret=interpret,
    )(x, vals, idx.astype(jnp.int8))

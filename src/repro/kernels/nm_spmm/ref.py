"""Pure-jnp oracle for the N:M sparse matmul kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.sparsity.sparse_params import nm_decompress


def nm_spmm_ref(x: jax.Array, vals: jax.Array, idx: jax.Array, *, n: int, m: int) -> jax.Array:
    w = nm_decompress(vals, idx, n, m)  # (K, N) dense with zeros
    return jnp.dot(x, w, preferred_element_type=jnp.float32).astype(x.dtype)

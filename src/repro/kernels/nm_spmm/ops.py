"""jit'd public wrapper for nm_spmm (TPU kernel / interpret / jnp oracle)."""
from __future__ import annotations

import jax

from repro.kernels.nm_spmm.nm_spmm import nm_spmm as _kernel
from repro.kernels.nm_spmm.ref import nm_spmm_ref


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def nm_spmm(x, vals, idx, *, n, m, interpret: bool = False, **tiles):
    if on_tpu() or interpret:
        return _kernel(
            x, vals, idx, n=n, m=m, interpret=interpret or not on_tpu(), **tiles
        )
    return nm_spmm_ref(x, vals, idx, n=n, m=m)

"""jit'd public wrapper for nm_spmm (TPU kernel / interpret / jnp oracle).

Observability accounting: the MXU work is the *dense-equivalent*
2·M·K·N (masking removes no multiplies — DESIGN.md §3), but the weight
traffic is the compressed vals+idx stream, which is exactly the
bandwidth win the kernel exists for; the booked bytes reflect that.
"""
from __future__ import annotations

import jax
import numpy as np

from repro.kernels import tuning
from repro.kernels.nm_spmm.nm_spmm import nm_spmm as _kernel
from repro.kernels.nm_spmm.ref import nm_spmm_ref
from repro.obs import trace as OT
from repro.obs.profile import is_abstract, record_kernel


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def nm_spmm(x, vals, idx, *, n, m, interpret: bool = False, **tiles):
    plan_src = None
    if (on_tpu() or interpret) and not tiles:
        tiles, plan_src = tuning.resolve(
            "nm_spmm",
            {"M": int(np.prod(x.shape[:-1])), "K": int(x.shape[-1]),
             "N": int(vals.shape[-1])},
            {"x": str(x.dtype), "v": str(vals.dtype)},
            {"n": int(n), "m": int(m)},
            interpret=interpret,
        )

    def run():
        if on_tpu() or interpret:
            return _kernel(
                x, vals, idx, n=n, m=m, interpret=interpret or not on_tpu(), **tiles
            )
        return nm_spmm_ref(x, vals, idx, n=n, m=m)

    if not OT.enabled() or is_abstract(x, vals, idx):
        return run()
    K = x.shape[-1]
    N = vals.shape[-1]
    rows = int(np.prod(x.shape[:-1]))
    flops = 2.0 * rows * K * N  # dense-equivalent MXU work
    traffic = (x.size * x.dtype.itemsize + vals.size * vals.dtype.itemsize
               + idx.size * idx.dtype.itemsize + rows * N * x.dtype.itemsize)
    attrs = dict(plan=plan_src, **tiles) if plan_src else None
    return record_kernel("kernels/nm_spmm", flops, traffic, run, attrs=attrs)


def call(*operands, interpret: bool = False, **params):
    """Uniform kernel entry point (see repro.kernels.dispatch): operands
    are ``(x, vals, idx)``, params must include ``n`` and ``m``."""
    return nm_spmm(*operands, interpret=interpret, **params)

"""Pure-jnp oracle for the masked matmul kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def masked_matmul_ref(x: jax.Array, w: jax.Array, m: jax.Array) -> jax.Array:
    """out = x @ (w ⊙ m), accumulated in f32, cast back to x.dtype."""
    wm = (w * m.astype(w.dtype)).astype(w.dtype)
    return jnp.dot(
        x, wm, preferred_element_type=jnp.float32
    ).astype(x.dtype)

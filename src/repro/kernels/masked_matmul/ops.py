"""jit'd public wrapper: dispatches to the Pallas kernel on TPU, to the
interpreted kernel under ``interpret=True`` (CPU validation), and to the
jnp oracle otherwise."""
from __future__ import annotations

import jax

from repro.kernels.masked_matmul.masked_matmul import masked_matmul as _kernel
from repro.kernels.masked_matmul.ref import masked_matmul_ref


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def masked_matmul(x, w, m, interpret: bool = False, **tiles):
    if on_tpu() or interpret:
        return _kernel(x, w, m, interpret=interpret or not on_tpu(), **tiles)
    return masked_matmul_ref(x, w, m)

"""jit'd public wrapper: dispatches to the Pallas kernel on TPU, to the
interpreted kernel under ``interpret=True`` (CPU validation), and to the
jnp oracle otherwise.

When observability is live (repro.obs) and the call is concrete (not
inside an outer jit trace), the invocation is fenced and booked against
the roofline model: 2·M·K·N FLOPs, x/w/mask/out HBM traffic.
"""
from __future__ import annotations

import jax
import numpy as np

from repro.kernels import tuning
from repro.kernels.masked_matmul.masked_matmul import masked_matmul as _kernel
from repro.kernels.masked_matmul.ref import masked_matmul_ref
from repro.obs import trace as OT
from repro.obs.profile import is_abstract, record_kernel


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def masked_matmul(x, w, m, interpret: bool = False, **tiles):
    plan_src = None
    if (on_tpu() or interpret) and not tiles:
        # only the kernel path has tiles to pick (the jnp oracle doesn't),
        # so cache hit-rates measure real launches, not ref-path calls
        tiles, plan_src = tuning.resolve(
            "masked_matmul",
            {"M": int(np.prod(x.shape[:-1])), "K": int(x.shape[-1]),
             "N": int(w.shape[-1])},
            {"x": str(x.dtype), "w": str(w.dtype)},
            interpret=interpret,
        )

    def run():
        if on_tpu() or interpret:
            return _kernel(x, w, m, interpret=interpret or not on_tpu(), **tiles)
        return masked_matmul_ref(x, w, m)

    if not OT.enabled() or is_abstract(x, w, m):
        return run()
    K, N = w.shape[-2], w.shape[-1]
    rows = int(np.prod(x.shape[:-1]))
    flops = 2.0 * rows * K * N
    traffic = (x.size * x.dtype.itemsize + w.size * w.dtype.itemsize
               + m.size * m.dtype.itemsize + rows * N * x.dtype.itemsize)
    attrs = dict(plan=plan_src, **tiles) if plan_src else None
    return record_kernel("kernels/masked_matmul", flops, traffic, run,
                         attrs=attrs)


def call(*operands, interpret: bool = False, **params):
    """Uniform kernel entry point (see repro.kernels.dispatch): operands
    are ``(x, w, m)``, params are the tile-size overrides."""
    return masked_matmul(*operands, interpret=interpret, **params)

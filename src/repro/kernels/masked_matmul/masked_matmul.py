"""Fused masked matmul Pallas TPU kernel:  out = x @ (w ⊙ m).

EBFT's hot spot: every forward of a sparse block computes (M ⊙ W)·X. A
naive implementation materializes the masked weight in HBM (a full extra
weight-sized read+write per step). This kernel fuses the mask application
into the matmul *prologue*: W and M tiles stream HBM→VMEM once, the
product W⊙M happens in VMEM registers immediately before the MXU dot, and
nothing weight-sized is ever written back.

The mask is carried as int8 (¼ the bf16 weight traffic, 2-bit packable in
a follow-up) — on TPU the benefit of sparsity is *bandwidth*, not MXU
FLOPs (no sparse systolic datapath), so the design goal is minimal bytes
moved, not skipped multiplies (DESIGN.md §3).

Grid: (M/bm, N/bn, K/bk), K minormost so the f32 accumulator tile lives in
VMEM scratch across the K sweep. Tile defaults are MXU-aligned (128).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, w_ref, m_ref, o_ref, acc_ref, *, k_steps: int):
    @pl.when(pl.program_id(2) == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # mask applied in VMEM, straight into the MXU
    wm = w_ref[...] * m_ref[...].astype(w_ref.dtype)
    acc_ref[...] += jnp.dot(
        x_ref[...], wm, preferred_element_type=jnp.float32
    )

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("bm", "bk", "bn", "interpret")
)
def masked_matmul(
    x: jax.Array,      # (M, K)
    w: jax.Array,      # (K, N)
    m: jax.Array,      # (K, N) int8/bool/float mask
    *,
    bm: int = 128,
    bk: int = 128,
    bn: int = 128,
    interpret: bool = False,
) -> jax.Array:
    M, K = x.shape
    K2, N = w.shape
    assert K == K2 and m.shape == (K, N), (x.shape, w.shape, m.shape)
    bm, bk, bn = min(bm, M), min(bk, K), min(bn, N)
    assert M % bm == 0 and K % bk == 0 and N % bn == 0, (
        f"shape ({M},{K},{N}) not divisible by tiles ({bm},{bk},{bn})"
    )
    k_steps = K // bk

    return pl.pallas_call(
        functools.partial(_kernel, k_steps=k_steps),
        grid=(M // bm, N // bn, k_steps),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, w, m.astype(jnp.int8))

"""Fused masked matmul Pallas TPU kernel:  out = x @ (w ⊙ m).

EBFT's hot spot: every forward of a sparse block computes (M ⊙ W)·X. A
naive implementation materializes the masked weight in HBM (a full extra
weight-sized read+write per step). This kernel fuses the mask application
into the matmul *prologue*: W and M tiles stream HBM→VMEM once, the
product W⊙M happens in VMEM registers immediately before the MXU dot, and
nothing weight-sized is ever written back.

The mask is carried as int8 (¼ the bf16 weight traffic, 2-bit packable in
a follow-up) — on TPU the benefit of sparsity is *bandwidth*, not MXU
FLOPs (no sparse systolic datapath), so the design goal is minimal bytes
moved, not skipped multiplies (DESIGN.md §3).

Grid: (M/bm, N/bn, K/bk), K minormost so the f32 accumulator tile lives in
VMEM scratch across the K sweep. Tile defaults are MXU-aligned (128).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.validation import plan_masked_matmul


def _kernel(x_ref, w_ref, m_ref, o_ref, acc_ref, *, k_steps: int):
    @pl.when(pl.program_id(2) == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # mask applied in VMEM, straight into the MXU
    wm = w_ref[...] * m_ref[...].astype(w_ref.dtype)
    acc_ref[...] += jnp.dot(
        x_ref[...], wm, preferred_element_type=jnp.float32
    )

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("bm", "bk", "bn", "interpret")
)
def masked_matmul(
    x: jax.Array,      # (M, K)
    w: jax.Array,      # (K, N)
    m: jax.Array,      # (K, N) int8/bool/float mask
    *,
    bm: int = 128,
    bk: int = 128,
    bn: int = 128,
    interpret: bool = False,
) -> jax.Array:
    M, K = x.shape
    K2, N = w.shape
    if K != K2 or m.shape != (K, N):
        raise ValueError(
            f"masked_matmul: inconsistent operand shapes x={x.shape} "
            f"w={w.shape} m={m.shape} (want x=(M,K), w=m=(K,N))"
        )
    # validates tile divisibility (after clamping to the problem shape) and
    # is the exact plan repro.analysis checks statically
    plan = plan_masked_matmul(
        M, K, N, bm=bm, bk=bk, bn=bn, x_dtype=x.dtype, w_dtype=w.dtype
    )
    k_steps = plan.grid[2]
    xb, wb, mb = plan.inputs
    (ob,) = plan.outputs

    return pl.pallas_call(
        functools.partial(_kernel, k_steps=k_steps),
        grid=plan.grid,
        in_specs=[
            pl.BlockSpec(xb.shape, xb.index_map),
            pl.BlockSpec(wb.shape, wb.index_map),
            pl.BlockSpec(mb.shape, mb.index_map),
        ],
        out_specs=pl.BlockSpec(ob.shape, ob.index_map),
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        scratch_shapes=[pltpu.VMEM(ob.shape, jnp.float32)],
        interpret=interpret,
    )(x, w, m.astype(jnp.int8))

"""Shape-keyed tile autotuner with a persistent plan cache (docs/PERF.md).

The three Pallas kernels default to MXU-aligned 128 tiles regardless of
problem shape. This module closes the gap the Triton-style stacks close
with ``@autotune``: for each *launch shape* it enumerates the valid tile
plans (through the same :mod:`repro.kernels.validation` builders the
kernels execute — a candidate that builds is a candidate that launches),
measures them (median of k fenced runs; interpret-mode Pallas on CPU so
CI exercises the identical path), and persists the winner in an on-disk
JSON cache so later processes start at the best plan with zero search
time.

Cache entries are keyed by ``(kernel, dims, dtypes, params, backend,
device_kind, code_rev)`` — ``code_rev`` is a hash of this package's
sources, so editing a kernel invalidates its entries by construction
(they simply stop matching; ``repro.analysis`` pass ``tuning_cache``
flags the stale leftovers). Writes are atomic (tmp + ``os.replace``).

Three modes, threaded through ``RunSpec --kernel-tune`` and the env::

    off     never consult the cache; kernels run their 128 defaults
    cache   use a cached plan when present, defaults on a miss (default
            for the launchers; free — one dict lookup per call)
    search  on a miss, run the measured search and persist the winner

Env overrides: ``REPRO_KERNEL_TUNE`` (mode), ``REPRO_KERNEL_CACHE``
(cache path). The module default is ``off`` so library users and the
test suite see bit-identical default-tile behavior unless they opt in.

Observability: resolution outcomes count into ``kernels/tuning/{hits,
misses,searches}`` and search wall time into ``kernels/tuning/search_s``
(null-registry no-ops when no run is live); :func:`stats` carries the
same numbers host-side for ``BENCH_ebft.json``'s ``kernel_tuning``
section regardless of obs state.
"""
from __future__ import annotations

import hashlib
import itertools
import json
import os
import tempfile
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.kernels.validation import (
    VMEM_BUDGET_BYTES,
    KernelPlan,
    plan_flash_attention,
    plan_masked_matmul,
    plan_nm_spmm,
)

SCHEMA = "repro.kernels.tuning/v1"
MODES = ("off", "cache", "search")
DEFAULT_CACHE_PATH = os.path.join("experiments", "kernel_cache.json")

# candidate tile sizes per axis, largest first (MXU/VPU want powers of
# two; the plan builders clamp to the problem dim and reject non-divisors)
TILE_OPTIONS = (256, 128, 64, 32)
# interpret-mode Pallas executes the grid step-by-step on the host; cap
# the grid so a CPU search never times a pathological 10k-step launch
INTERPRET_GRID_CAP = 256


# ---------------------------------------------------------------------------
# module state: mode, cache path, loaded cache, resolution stats
# ---------------------------------------------------------------------------
class _State:
    __slots__ = ("mode", "path", "cache", "loaded", "stats")

    def __init__(self) -> None:
        self.mode = os.environ.get("REPRO_KERNEL_TUNE", "off")
        self.path = os.environ.get("REPRO_KERNEL_CACHE", DEFAULT_CACHE_PATH)
        self.cache: Dict[str, Dict[str, Any]] = {}
        self.loaded = False
        self.stats = _zero_stats()


def _zero_stats() -> Dict[str, float]:
    return {"hits": 0, "misses": 0, "searches": 0, "search_s": 0.0}


_STATE = _State()


def configure(mode: Optional[str] = None, path: Optional[str] = None) -> None:
    """Set the resolution mode and/or cache path (None = keep current).

    Changing the path drops the in-memory cache so the next resolve
    reloads from disk.
    """
    if mode is not None:
        if mode not in MODES:
            raise ValueError(
                f"kernel-tune mode {mode!r} not one of {'/'.join(MODES)}"
            )
        _STATE.mode = mode
    if path is not None and path != _STATE.path:
        _STATE.path = path
        _STATE.cache = {}
        _STATE.loaded = False


def state() -> Dict[str, Any]:
    """Current knobs: mode, cache path, in-memory entry count."""
    return {"mode": _STATE.mode, "path": _STATE.path,
            "entries": len(_STATE.cache)}


def stats() -> Dict[str, float]:
    """Resolution counters since the last :func:`reset_stats`."""
    return dict(_STATE.stats)


def reset_stats() -> None:
    _STATE.stats = _zero_stats()


def _reset_for_tests(mode: str = "off") -> None:
    """Test hook: fresh state, no env influence."""
    _STATE.mode = mode
    _STATE.path = DEFAULT_CACHE_PATH
    _STATE.cache = {}
    _STATE.loaded = False
    _STATE.stats = _zero_stats()


# ---------------------------------------------------------------------------
# cache key / persistence
# ---------------------------------------------------------------------------
_CODE_REV: Optional[str] = None


def code_rev() -> str:
    """Hash of every source file in this package: the cache's staleness
    fence. An edited kernel (or tuner) makes old entries miss naturally;
    the ``tuning_cache`` analysis pass flags them for cleanup."""
    global _CODE_REV
    if _CODE_REV is None:
        h = hashlib.sha1()
        root = os.path.dirname(os.path.abspath(__file__))
        for dirpath, _dirs, files in sorted(os.walk(root)):
            for fn in sorted(files):
                if fn.endswith(".py"):
                    with open(os.path.join(dirpath, fn), "rb") as f:
                        h.update(fn.encode())
                        h.update(f.read())
        _CODE_REV = h.hexdigest()[:12]
    return _CODE_REV


def _backend_tag(interpret: bool) -> str:
    import jax

    tag = jax.default_backend()
    return f"{tag}+interpret" if interpret and tag != "cpu" else tag


def _device_kind() -> str:
    import jax

    try:
        return jax.devices()[0].device_kind
    except Exception:
        return "unknown"


def _fmt(d: Dict[str, Any]) -> str:
    return ",".join(f"{k}={d[k]}" for k in sorted(d))


def cache_key(kernel: str, dims: Dict[str, int], dtypes: Dict[str, str],
              params: Dict[str, Any], backend: str, device_kind: str,
              rev: str) -> str:
    return "|".join([kernel, _fmt(dims), _fmt(dtypes), _fmt(params),
                     backend, device_kind, rev])


def _load() -> None:
    if _STATE.loaded:
        return
    _STATE.loaded = True
    try:
        with open(_STATE.path) as f:
            payload = json.load(f)
    except (OSError, json.JSONDecodeError):
        return
    if not isinstance(payload, dict) or payload.get("schema") != SCHEMA:
        return  # unknown schema version: start fresh, never crash a run
    entries = payload.get("entries")
    if isinstance(entries, dict):
        _STATE.cache = entries


def _save() -> None:
    """Atomic rewrite: the cache is either the old file or the new one,
    never a torn write (parallel CI jobs share the path)."""
    path = _STATE.path
    parent = os.path.dirname(path) or "."
    os.makedirs(parent, exist_ok=True)
    payload = {"schema": SCHEMA, "code_rev": code_rev(),
               "entries": _STATE.cache}
    fd, tmp = tempfile.mkstemp(dir=parent, suffix=".tmp")
    try:
        with os.fdopen(fd, "w") as f:
            json.dump(payload, f, indent=1, sort_keys=True)
        os.replace(tmp, path)
    except OSError:
        try:
            os.unlink(tmp)
        except OSError:
            pass


# ---------------------------------------------------------------------------
# candidate generation (through the validated plan builders)
# ---------------------------------------------------------------------------
_PLANNERS: Dict[str, Tuple[Tuple[str, ...], Callable[..., KernelPlan]]] = {
    "masked_matmul": (
        ("bm", "bk", "bn"),
        lambda dims, dtypes, params, tiles: plan_masked_matmul(
            dims["M"], dims["K"], dims["N"], **tiles,
            x_dtype=dtypes.get("x", "float32"),
            w_dtype=dtypes.get("w", "float32"),
        ),
    ),
    "nm_spmm": (
        ("bm", "bk", "bn"),
        lambda dims, dtypes, params, tiles: plan_nm_spmm(
            dims["M"], dims["K"], dims["N"],
            n=params["n"], m=params["m"], **tiles,
            x_dtype=dtypes.get("x", "float32"),
            v_dtype=dtypes.get("v", "float32"),
        ),
    ),
    "flash_attention": (
        ("bq", "bk"),
        lambda dims, dtypes, params, tiles: plan_flash_attention(
            dims["BH"], dims["Sq"], dims["Sk"], dims["d"], **tiles,
            q_dtype=dtypes.get("q", "float32"),
        ),
    ),
}


def build_plan(kernel: str, dims: Dict[str, int], dtypes: Dict[str, str],
               params: Dict[str, Any], tiles: Dict[str, int]) -> KernelPlan:
    """The KernelPlan a launch with these tiles would execute (raises
    ``ValueError`` exactly where the kernel itself would)."""
    if kernel not in _PLANNERS:
        raise ValueError(f"unknown kernel {kernel!r}; "
                         f"tunable: {', '.join(_PLANNERS)}")
    names, builder = _PLANNERS[kernel]
    bad = set(tiles) - set(names)
    if bad:
        raise ValueError(f"{kernel}: unknown tile knobs {sorted(bad)}")
    return builder(dims, dtypes, params, tiles)


def candidate_tiles(
    kernel: str,
    dims: Dict[str, int],
    dtypes: Dict[str, str],
    params: Optional[Dict[str, Any]] = None,
    *,
    interpret: bool = False,
    max_candidates: int = 8,
) -> List[Dict[str, int]]:
    """Valid, deduplicated tile plans for this launch, default plan first.

    Every candidate passes the full :class:`KernelPlan` validation (grid
    divisibility after clamping, N:M group alignment) plus the VMEM
    double-buffering budget; interpret-mode candidates additionally
    respect :data:`INTERPRET_GRID_CAP`. Distinct requests that clamp to
    the same effective tiles collapse to one candidate.
    """
    params = params or {}
    names, _ = _PLANNERS[kernel] if kernel in _PLANNERS else ((), None)
    out: List[Dict[str, int]] = []
    seen: set = set()

    def admit(tiles: Dict[str, int]) -> None:
        try:
            plan = build_plan(kernel, dims, dtypes, params, tiles)
        except ValueError:
            return
        eff = tuple(sorted(plan.tiles.items()))
        if eff in seen:
            return
        if plan.vmem_bytes() > VMEM_BUDGET_BYTES:
            return
        if interpret and int(np.prod(plan.grid)) > INTERPRET_GRID_CAP:
            return
        seen.add(eff)
        out.append(dict(plan.tiles))

    admit({})  # the 128-defaults plan is always candidate 0
    for combo in itertools.product(TILE_OPTIONS, repeat=len(names)):
        if len(out) >= max_candidates:
            break
        admit(dict(zip(names, combo)))
    return out


# ---------------------------------------------------------------------------
# measured search
# ---------------------------------------------------------------------------
def _make_runner(kernel: str, dims: Dict[str, int], dtypes: Dict[str, str],
                 params: Dict[str, Any], interpret: bool) -> Callable:
    """A ``tiles -> output`` closure over synthesized operands.

    The search owns its operands (seeded numpy, shaped from ``dims``), so
    it can run from anywhere — including while an outer jit is tracing
    the real call site — and measures the kernel, not the caller's data.
    """
    import jax
    import jax.numpy as jnp

    rng = np.random.default_rng(0)
    run_interpret = interpret or jax.default_backend() != "tpu"

    if kernel == "masked_matmul":
        from repro.kernels.masked_matmul.masked_matmul import masked_matmul

        x = jnp.asarray(rng.normal(size=(dims["M"], dims["K"])),
                        dtypes.get("x", "float32"))
        w = jnp.asarray(rng.normal(size=(dims["K"], dims["N"])),
                        dtypes.get("w", "float32"))
        m = jnp.asarray(rng.random((dims["K"], dims["N"])) > 0.5, jnp.int8)
        return lambda tiles: masked_matmul(
            x, w, m, interpret=run_interpret, **tiles)

    if kernel == "nm_spmm":
        from repro.kernels.nm_spmm.nm_spmm import nm_spmm

        K, N = dims["K"], dims["N"]
        n, m = params["n"], params["m"]
        G = K // m
        # one valid N:M pattern per (group, col): n distinct offsets in [0, m)
        perm = rng.permuted(
            np.broadcast_to(np.arange(m), (G, N, m)).copy(), axis=2)
        idx = np.sort(perm[:, :, :n], axis=2)          # (G, N, n)
        idx = jnp.asarray(
            idx.transpose(0, 2, 1).reshape(G * n, N), jnp.int8)
        vals = jnp.asarray(rng.normal(size=(G * n, N)),
                           dtypes.get("v", "float32"))
        x = jnp.asarray(rng.normal(size=(dims["M"], K)),
                        dtypes.get("x", "float32"))
        return lambda tiles: nm_spmm(
            x, vals, idx, n=n, m=m, interpret=run_interpret, **tiles)

    if kernel == "flash_attention":
        from repro.kernels.flash_attention.flash_attention import (
            flash_attention,
        )

        dt = dtypes.get("q", "float32")
        q = jnp.asarray(rng.normal(size=(dims["BH"], dims["Sq"], dims["d"])), dt)
        k = jnp.asarray(rng.normal(size=(dims["BH"], dims["Sk"], dims["d"])), dt)
        v = jnp.asarray(rng.normal(size=(dims["BH"], dims["Sk"], dims["d"])), dt)
        causal = bool(params.get("causal", True))
        return lambda tiles: flash_attention(
            q, k, v, causal=causal, interpret=run_interpret, **tiles)

    raise ValueError(f"unknown kernel {kernel!r}")


def _timed(run: Callable[[], Any], reps: int) -> float:
    """Median of ``reps`` fenced runs, after one untimed warm-up call
    (compile must not contaminate the comparison)."""
    import jax

    jax.block_until_ready(run())
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(run())
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def search(
    kernel: str,
    dims: Dict[str, int],
    dtypes: Dict[str, str],
    params: Optional[Dict[str, Any]] = None,
    *,
    interpret: bool = False,
    reps: int = 3,
    max_candidates: int = 8,
) -> Dict[str, Any]:
    """Measure every candidate plan; return the cache entry for the best.

    The default plan is measured *inside the same sweep*, so
    ``measured_s["best"] <= measured_s["default"]`` holds by construction
    (exact ties keep the default — ``min`` is stable) and the
    BENCH_kernels default-vs-tuned comparison is never a cross-sweep
    noise artifact.
    """
    params = params or {}
    cands = candidate_tiles(kernel, dims, dtypes, params,
                            interpret=interpret, max_candidates=max_candidates)
    if not cands:
        raise ValueError(
            f"{kernel}: no valid tile plan for dims {dims} "
            f"(params {params})"
        )
    runner = _make_runner(kernel, dims, dtypes, params, interpret)
    measured = [(_timed(lambda t=tiles: runner(t), reps), tiles)
                for tiles in cands]
    best_s, best_tiles = min(measured, key=lambda r: r[0])
    return {
        "kernel": kernel,
        "dims": dict(dims),
        "dtypes": dict(dtypes),
        "params": dict(params),
        "backend": _backend_tag(interpret),
        "device_kind": _device_kind(),
        "code_rev": code_rev(),
        "tiles": dict(best_tiles),
        "measured_s": {"default": measured[0][0], "best": best_s},
        "candidates": len(cands),
    }


def store(entry: Dict[str, Any]) -> str:
    """Insert a :func:`search` entry into the persistent cache; returns
    its key. The BENCH_kernels sweep uses this so its default-vs-tuned
    measurements double as warm cache entries for later runs."""
    _load()
    key = cache_key(entry["kernel"], entry["dims"], entry["dtypes"],
                    entry["params"], entry["backend"], entry["device_kind"],
                    entry["code_rev"])
    _STATE.cache[key] = entry
    _save()
    return key


# ---------------------------------------------------------------------------
# resolution (the wrappers' entry point)
# ---------------------------------------------------------------------------
def resolve(
    kernel: str,
    dims: Dict[str, int],
    dtypes: Dict[str, str],
    params: Optional[Dict[str, Any]] = None,
    *,
    interpret: bool = False,
) -> Tuple[Dict[str, int], Optional[str]]:
    """Tiles for this launch per the current mode.

    Returns ``(tiles, source)`` where source is ``"cache"``, ``"search"``,
    ``"default"`` (a cache-mode miss), or ``None`` (tuning off — the
    empty tile dict means the kernel runs its built-in defaults). Cached
    tiles are re-validated through the plan builder before use; a
    corrupt or stale-constraint entry degrades to a miss, never a crash.
    """
    from repro.obs import metrics as OM

    if _STATE.mode == "off":
        return {}, None
    params = params or {}
    _load()
    key = cache_key(kernel, dims, dtypes, params, _backend_tag(interpret),
                    _device_kind(), code_rev())
    entry = _STATE.cache.get(key)
    if entry is not None:
        tiles = entry.get("tiles")
        if isinstance(tiles, dict):
            try:
                tiles = {k: int(v) for k, v in tiles.items()}
                build_plan(kernel, dims, dtypes, params, tiles)
            except (ValueError, TypeError):
                entry = None  # invalid entry: fall through to a miss
        else:
            entry = None
    if entry is not None:
        _STATE.stats["hits"] += 1
        OM.counter("kernels/tuning/hits").inc()
        return tiles, "cache"

    _STATE.stats["misses"] += 1
    OM.counter("kernels/tuning/misses").inc()
    if _STATE.mode != "search":
        return {}, "default"

    t0 = time.perf_counter()
    entry = search(kernel, dims, dtypes, params, interpret=interpret)
    dt = time.perf_counter() - t0
    _STATE.stats["searches"] += 1
    _STATE.stats["search_s"] += dt
    OM.counter("kernels/tuning/searches").inc()
    OM.histogram("kernels/tuning/search_s").observe(dt)
    _STATE.cache[key] = entry
    _save()
    return dict(entry["tiles"]), "search"


# ---------------------------------------------------------------------------
# workload pre-tuning (launchers warm the cache before the hot path)
# ---------------------------------------------------------------------------
def ebft_workloads(cfg, tokens: int, seq: int,
                   pattern: Optional[Tuple[int, int]] = None) -> List[Tuple]:
    """(kernel, dims, dtypes, params) for every kernel launch an EBFT
    calibration walk over this config could make: one masked matmul per
    distinct block weight shape (M = microbatch x seq calibration
    tokens), the N:M variant when a pattern divides K, and the per-block
    flash attention at the calibration sequence length."""
    from repro.analysis.kernel_check import matmul_workloads

    f32 = "float32"
    work: List[Tuple] = []
    seen: set = set()
    for _label, M, K, N in matmul_workloads(cfg, tokens=tokens):
        if (M, K, N) in seen:
            continue
        seen.add((M, K, N))
        dims = {"M": M, "K": K, "N": N}
        work.append(("masked_matmul", dims, {"x": f32, "w": f32}, {}))
        if pattern is not None and K % pattern[1] == 0:
            work.append(("nm_spmm", dims, {"x": f32, "v": f32},
                         {"n": pattern[0], "m": pattern[1]}))
    if cfg.family != "ssm":
        mb = max(tokens // max(seq, 1), 1)
        work.append((
            "flash_attention",
            {"BH": mb * cfg.num_heads, "Sq": seq, "Sk": seq,
             "d": cfg.resolved_head_dim},
            {"q": f32}, {"causal": True},
        ))
    return work


def pretune(workloads: Sequence[Tuple], *, interpret: bool = False) -> List[Dict]:
    """Resolve each workload through the current mode (searching and
    persisting on misses when mode is ``search``); returns one record per
    workload for the launcher's log/artifact."""
    out = []
    for kernel, dims, dtypes, params in workloads:
        tiles, source = resolve(kernel, dims, dtypes, params,
                                interpret=interpret)
        out.append({"kernel": kernel, "dims": dict(dims),
                    "source": source, "tiles": tiles})
    return out

"""Custom-kernel registry: one entry point, uniform signatures.

Every kernel package under here exposes ``ops.call(*operands,
interpret=False, **params)`` — the uniform wrapper signature — plus its
historical named entry points. :func:`dispatch` is the single way in:

    from repro import kernels
    out = kernels.dispatch("masked_matmul", x, w, m)
    out = kernels.dispatch("flash_attention", q, k, v, layout="bshd")

Dispatch resolves lazily (importing ``repro.kernels`` never imports jax
or Pallas), so the registry is safe to touch from tooling. The old names
(``kernels.masked_matmul`` etc.) remain as thin aliases over dispatch.

On the kernel path (TPU or ``interpret=True``) every wrapper resolves
its tile plan through :mod:`repro.kernels.tuning` when the caller passes
no explicit tiles: a shape-keyed autotuner with a persistent plan cache
(``--kernel-tune {off,cache,search}`` on the launchers; docs/PERF.md).
Explicit tile kwargs always win, and mode ``off`` (the library default)
is byte-identical to the pre-tuner behavior.

This layer is OPTIONAL per-paper: packages exist only for compute
hot-spots the paper itself optimizes (DESIGN.md §Kernels).
"""
from __future__ import annotations

import importlib
from typing import Any, Tuple

_REGISTRY = {
    "masked_matmul": "repro.kernels.masked_matmul.ops",
    "nm_spmm": "repro.kernels.nm_spmm.ops",
    "flash_attention": "repro.kernels.flash_attention.ops",
}


def names() -> Tuple[str, ...]:
    """Registered kernel names, stable order."""
    return tuple(_REGISTRY)


def dispatch(name: str, *operands: Any, interpret: bool = False, **params: Any):
    """Run kernel ``name`` on ``operands`` through its uniform wrapper.

    The wrapper picks the backend (Pallas on TPU, interpreted Pallas
    under ``interpret=True``, jnp oracle otherwise) and books roofline
    accounting when observability is live.
    """
    module = _REGISTRY.get(name)
    if module is None:
        raise KeyError(
            f"unknown kernel {name!r}; registered: {', '.join(_REGISTRY)}"
        )
    mod = importlib.import_module(module)
    _restore_aliases()
    return mod.call(*operands, interpret=interpret, **params)


# thin aliases: the pre-dispatch spellings, kept for existing callers
def masked_matmul(*operands, interpret: bool = False, **params):
    return dispatch("masked_matmul", *operands, interpret=interpret, **params)


def nm_spmm(*operands, interpret: bool = False, **params):
    return dispatch("nm_spmm", *operands, interpret=interpret, **params)


def flash_attention(*operands, interpret: bool = False, **params):
    return dispatch("flash_attention", *operands, interpret=interpret, **params)


def flash_attention_bshd(*operands, interpret: bool = False, **params):
    return dispatch("flash_attention", *operands, interpret=interpret,
                    layout="bshd", **params)


_ALIASES = {
    "masked_matmul": masked_matmul,
    "nm_spmm": nm_spmm,
    "flash_attention": flash_attention,
}


def _restore_aliases() -> None:
    # importing a subpackage rebinds its name on this package (standard
    # Python submodule semantics), shadowing the same-named alias above;
    # rebind the callables so `kernels.masked_matmul(...)` keeps working
    g = globals()
    for name, fn in _ALIASES.items():
        if not callable(g.get(name)):
            g[name] = fn

"""Shared shape/tile validation and static kernel plans.

Every Pallas kernel in this package validates its launch through the
helpers here, raising ``ValueError`` (``assert`` disappears under
``python -O``). The same helpers back the static analyzer
(``repro.analysis.kernel_check``): the plan a kernel executes is the plan
the analyzer checks, so CI findings and runtime errors can never drift
apart (docs/ANALYSIS.md).

A :class:`KernelPlan` is the static footprint of one ``pl.pallas_call``:
the grid, every BlockSpec (shape + index map), and the scratch buffers.
From it the analyzer derives

  * tile divisibility (already enforced — building a plan validates),
  * a per-grid-step VMEM estimate: streamed blocks are double-buffered by
    the Pallas pipeline (2x), scratch is resident once, against the
    ~16 MiB per-core VMEM budget (DESIGN.md §3),
  * BlockSpec index-map arity vs. grid rank consistency.
"""
from __future__ import annotations

import dataclasses
import inspect
from typing import Callable, Dict, Optional, Tuple

import jax.numpy as jnp

# Per-core VMEM on current TPU generations (v4/v5e/v5p: ~16 MiB usable).
VMEM_BUDGET_BYTES = 16 * 1024 * 1024


def clamp_tiles(dims: Dict[str, int], tiles: Dict[str, int]) -> Dict[str, int]:
    """Clamp each requested tile to its dimension (a 128-default tile on a
    64-wide problem simply becomes 64). Returns the clamped tile dict."""
    return {t: min(tiles[t], dims[t]) for t in tiles}


def pick_tile(
    dim: int, preferred: int = 128, minimum: int = 8, multiple_of: int = 1
) -> Optional[int]:
    """Largest viable tile for ``dim``: the preferred size if it divides,
    else halvings of it down to ``minimum`` (MXU/VPU lanes want powers of
    two), else ``dim`` itself when the whole dimension fits in one tile.
    ``multiple_of`` constrains candidates (nm_spmm tiles must align with
    M-groups). Returns ``None`` when no viable tile exists — the static
    analyzer reports that as KER001 rather than guessing."""
    if dim <= 0:
        return None
    if dim <= preferred:
        return dim if dim % multiple_of == 0 else None
    t = preferred
    while t >= minimum:
        if dim % t == 0 and t % multiple_of == 0:
            return t
        t //= 2
    return None


def require_divisible(
    kernel: str,
    dims: Dict[str, int],
    requested: Dict[str, int],
    clamped: Dict[str, int],
) -> None:
    """Raise ``ValueError`` for every dimension its (clamped) tile does not
    divide, reporting both the requested and the effective tile so the
    clamp-then-check behaviour is visible in the message."""
    bad = []
    for t, dim_name in zip(requested, dims):
        dim, tile = dims[dim_name], clamped[t]
        if tile <= 0 or dim % tile != 0:
            note = (
                f"{dim_name}={dim} not divisible by {t}={tile}"
                + (f" (requested {t}={requested[t]}, clamped to {tile})"
                   if requested[t] != tile else "")
            )
            bad.append(note)
    if bad:
        raise ValueError(f"{kernel}: " + "; ".join(bad))


@dataclasses.dataclass(frozen=True)
class BlockUse:
    """One VMEM-resident buffer of a kernel: a streamed input/output block
    (with its BlockSpec index map) or a scratch allocation (index_map None)."""

    name: str
    shape: Tuple[int, ...]
    dtype: jnp.dtype
    index_map: Optional[Callable] = None

    @property
    def bytes(self) -> int:
        n = 1
        for d in self.shape:
            n *= d
        return n * jnp.dtype(self.dtype).itemsize


@dataclasses.dataclass(frozen=True)
class KernelPlan:
    kernel: str
    grid: Tuple[int, ...]
    inputs: Tuple[BlockUse, ...]
    outputs: Tuple[BlockUse, ...]
    scratch: Tuple[BlockUse, ...]
    tiles: Dict[str, int] = dataclasses.field(default_factory=dict)

    def vmem_bytes(self) -> int:
        """Streamed blocks double-buffer (pipeline prefetch), scratch is
        resident once."""
        streamed = sum(b.bytes for b in self.inputs + self.outputs)
        return 2 * streamed + sum(b.bytes for b in self.scratch)

    def index_map_arity_errors(self) -> Tuple[str, ...]:
        """BlockSpec index maps must take exactly one argument per grid
        axis — a mismatch is a latent pallas_call failure."""
        errs = []
        rank = len(self.grid)
        for b in self.inputs + self.outputs:
            if b.index_map is None:
                continue
            arity = len(inspect.signature(b.index_map).parameters)
            if arity != rank:
                errs.append(
                    f"{self.kernel}/{b.name}: index map takes {arity} args "
                    f"but grid has rank {rank}"
                )
        return tuple(errs)


# ---------------------------------------------------------------------------
# per-kernel plans (each kernel builds its pallas_call FROM its plan)
# ---------------------------------------------------------------------------
def plan_masked_matmul(
    M: int, K: int, N: int,
    *,
    bm: int = 128, bk: int = 128, bn: int = 128,
    x_dtype=jnp.float32, w_dtype=jnp.float32,
) -> KernelPlan:
    dims = {"M": M, "K": K, "N": N}
    req = {"bm": bm, "bk": bk, "bn": bn}
    tiles = clamp_tiles({"bm": M, "bk": K, "bn": N}, req)
    require_divisible("masked_matmul", dims, req, tiles)
    bm, bk, bn = tiles["bm"], tiles["bk"], tiles["bn"]
    grid = (M // bm, N // bn, K // bk)
    return KernelPlan(
        kernel="masked_matmul",
        grid=grid,
        inputs=(
            BlockUse("x", (bm, bk), jnp.dtype(x_dtype), lambda i, j, k: (i, k)),
            BlockUse("w", (bk, bn), jnp.dtype(w_dtype), lambda i, j, k: (k, j)),
            BlockUse("m", (bk, bn), jnp.dtype(jnp.int8), lambda i, j, k: (k, j)),
        ),
        outputs=(
            BlockUse("out", (bm, bn), jnp.dtype(x_dtype), lambda i, j, k: (i, j)),
        ),
        scratch=(BlockUse("acc", (bm, bn), jnp.dtype(jnp.float32)),),
        tiles=tiles,
    )


def plan_nm_spmm(
    M: int, K: int, N: int,
    *,
    n: int, m: int,
    bm: int = 128, bk: int = 128, bn: int = 128,
    x_dtype=jnp.float32, v_dtype=jnp.float32,
) -> KernelPlan:
    if not (0 < n <= m):
        raise ValueError(f"nm_spmm: invalid N:M pattern {n}:{m}")
    if K % m != 0:
        raise ValueError(f"nm_spmm: K={K} not divisible by M-group size m={m}")
    dims = {"M": M, "K": K, "N": N}
    req = {"bm": bm, "bk": bk, "bn": bn}
    tiles = clamp_tiles({"bm": M, "bk": K, "bn": N}, req)
    require_divisible("nm_spmm", dims, req, tiles)
    bm, bk, bn = tiles["bm"], tiles["bk"], tiles["bn"]
    if bk % m != 0:
        raise ValueError(
            f"nm_spmm: bk={bk} must align with M-groups of {m}"
            + (f" (requested bk={req['bk']}, clamped to {bk})"
               if req["bk"] != bk else "")
        )
    grid = (M // bm, N // bn, K // bk)
    bkc = bk // m * n  # compressed rows per K tile
    return KernelPlan(
        kernel="nm_spmm",
        grid=grid,
        inputs=(
            BlockUse("x", (bm, bk), jnp.dtype(x_dtype), lambda i, j, k: (i, k)),
            BlockUse("vals", (bkc, bn), jnp.dtype(v_dtype), lambda i, j, k: (k, j)),
            BlockUse("idx", (bkc, bn), jnp.dtype(jnp.int8), lambda i, j, k: (k, j)),
        ),
        outputs=(
            BlockUse("out", (bm, bn), jnp.dtype(x_dtype), lambda i, j, k: (i, j)),
        ),
        scratch=(
            BlockUse("acc", (bm, bn), jnp.dtype(jnp.float32)),
            # the decompressed dense tile is VMEM-register resident too
            BlockUse("dense_tile", (bk, bn), jnp.dtype(v_dtype)),
        ),
        tiles=tiles,
    )


def plan_flash_attention(
    BH: int, Sq: int, Sk: int, d: int,
    *,
    bq: int = 128, bk: int = 128,
    q_dtype=jnp.float32,
) -> KernelPlan:
    dims = {"Sq": Sq, "Sk": Sk}
    req = {"bq": bq, "bk": bk}
    tiles = clamp_tiles({"bq": Sq, "bk": Sk}, req)
    require_divisible("flash_attention", dims, req, tiles)
    bq, bk = tiles["bq"], tiles["bk"]
    grid = (BH, Sq // bq, Sk // bk)
    dt = jnp.dtype(q_dtype)
    return KernelPlan(
        kernel="flash_attention",
        grid=grid,
        inputs=(
            BlockUse("q", (1, bq, d), dt, lambda b, i, j: (b, i, 0)),
            BlockUse("k", (1, bk, d), dt, lambda b, i, j: (b, j, 0)),
            BlockUse("v", (1, bk, d), dt, lambda b, i, j: (b, j, 0)),
        ),
        outputs=(
            BlockUse("out", (1, bq, d), dt, lambda b, i, j: (b, i, 0)),
        ),
        scratch=(
            BlockUse("m", (bq, 1), jnp.dtype(jnp.float32)),
            BlockUse("l", (bq, 1), jnp.dtype(jnp.float32)),
            BlockUse("acc", (bq, d), jnp.dtype(jnp.float32)),
            # the (bq, bk) score/probability tile is VMEM-register resident
            BlockUse("scores", (bq, bk), jnp.dtype(jnp.float32)),
        ),
        tiles=tiles,
    )

"""Batched serving: prefill + decode loops with continuous batching.

``Server`` wraps a Model with jitted prefill/decode steps and a minimal
continuous-batching scheduler: a fixed pool of B slots; finished sequences
free their slot and queued requests are prefilled into it. The KV cache is
allocated once (B, max_len) and slots are recycled — the paper-relevant
part is that sparse (EBFT-fine-tuned) weights drop straight in, since the
serve path reads the same param pytree as training.

Decode sampling is greedy or temperature; everything is jit-compiled once
per (batch, len) bucket.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs import metrics as OM
from repro.obs import trace as OT
from repro.obs.profile import profiled


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray  # (S,) int32
    max_new: int = 32
    out: Optional[List[int]] = None


class Server:
    def __init__(self, model, params, batch_size: int, max_len: int, temperature: float = 0.0):
        self.model = model
        self.params = params
        self.B = batch_size
        self.max_len = max_len
        self.temperature = temperature

        # profiled: compile time vs execution time per (batch, len) bucket
        # (zero-overhead passthrough while observability is off)
        self._prefill = profiled(jax.jit(model.prefill), "serve/prefill")
        self._decode = profiled(jax.jit(model.decode_step), "serve/decode")

    def _sample(self, logits: jax.Array, rng) -> jax.Array:
        logits = logits[:, -1]
        if self.temperature <= 0:
            return jnp.argmax(logits, axis=-1)
        return jax.random.categorical(rng, logits / self.temperature, axis=-1)

    def generate(self, prompts: List[np.ndarray], max_new: int = 32, seed: int = 0):
        """One-shot batched generation (prompts padded to a bucket)."""
        assert len(prompts) <= self.B
        B = len(prompts)
        S = max(len(p) for p in prompts)
        toks = np.zeros((B, S), np.int32)
        for i, p in enumerate(prompts):
            toks[i, S - len(p):] = p  # left-pad so last position aligns
        state = self.model.init_serve_state(B, S + max_new)
        batch = {"tokens": jnp.asarray(toks)}
        logits, state = self._prefill(self.params, batch, state)
        rng = jax.random.PRNGKey(seed)
        outs = [[] for _ in range(B)]
        tok = self._sample(logits, rng)
        for step in range(max_new):
            for i in range(B):
                outs[i].append(int(tok[i]))
            rng, sub = jax.random.split(rng)
            logits, state = self._decode(self.params, tok[:, None].astype(jnp.int32), state)
            tok = self._sample(logits, sub)
        return outs

    # ------------------------------------------------------------------
    def serve(self, requests: List[Request], seed: int = 0) -> Dict[int, List[int]]:
        """Continuous batching: slots are freed as sequences finish and
        refilled from the queue. Single-slot prefill keeps the example
        simple; a production server would bucket prefills."""
        queue = list(requests)
        results: Dict[int, List[int]] = {}
        active: List[Optional[Request]] = [None] * self.B
        remaining = np.zeros(self.B, np.int64)
        state = self.model.init_serve_state(self.B, self.max_len)
        last_tok = jnp.zeros((self.B, 1), jnp.int32)
        rng = jax.random.PRNGKey(seed)
        obs_on = OT.enabled()
        tokens_out = 0
        t_start = time.perf_counter()

        def admit():
            nonlocal state, last_tok
            for slot in range(self.B):
                if active[slot] is None and queue:
                    req = queue.pop(0)
                    active[slot] = req
                    req.out = []
                    remaining[slot] = req.max_new
                    # single-sequence prefill into this slot
                    sub = self.model.init_serve_state(1, self.max_len)
                    logits, sub = self._prefill(
                        self.params, {"tokens": jnp.asarray(req.prompt[None])}, sub
                    )
                    state = jax.tree.map(
                        lambda full, one: _slot_update(full, one, slot), state, sub
                    )
                    tok = int(jnp.argmax(logits[0, -1]))
                    req.out.append(tok)
                    last_tok = last_tok.at[slot, 0].set(tok)
                    remaining[slot] -= 1
            if obs_on:
                OM.gauge("serve/queue_depth").set(len(queue))

        with OT.span("serve/batch", requests=len(requests), slots=self.B):
            admit()
            while any(a is not None for a in active):
                if obs_on:
                    # occupancy: fraction of slots doing useful decode work
                    OM.histogram("serve/batch_occupancy").observe(
                        sum(1 for a in active if a is not None) / self.B
                    )
                rng, sub = jax.random.split(rng)
                logits, state = self._decode(self.params, last_tok, state)
                tok = self._sample(logits, sub)
                for slot in range(self.B):
                    req = active[slot]
                    if req is None:
                        continue
                    t = int(tok[slot])
                    req.out.append(t)
                    remaining[slot] -= 1
                    tokens_out += 1
                    if remaining[slot] <= 0:
                        results[req.uid] = req.out
                        active[slot] = None
                last_tok = tok[:, None].astype(jnp.int32)
                admit()
            if obs_on:
                dt = time.perf_counter() - t_start
                tokens_out += len(results)  # one prefill token per request
                OM.counter("serve/tokens").inc(tokens_out)
                OM.counter("serve/requests").inc(len(results))
                OM.gauge("serve/tokens_per_s").set(tokens_out / max(dt, 1e-9))
        return results


def _slot_update(full: jax.Array, one: jax.Array, slot: int) -> jax.Array:
    """Write a single-sequence state into batch slot ``slot``. Batch dim is
    the first dim where shapes differ (full=B, one=1); scalars merge by max
    (the shared ``len`` counter)."""
    if full.ndim == 0:
        return jnp.maximum(full, one)
    for axis in range(full.ndim):
        if full.shape[axis] != one.shape[axis]:
            idx = [slice(None)] * full.ndim
            idx[axis] = slice(slot, slot + 1)
            return full.at[tuple(idx)].set(one.astype(full.dtype))
    return one.astype(full.dtype)  # identical shapes: shared state

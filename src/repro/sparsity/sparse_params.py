"""Masked parameter pytrees + sparsity pattern utilities.

Central conventions used by every pruning method and by EBFT:

* A **mask pytree** mirrors the param pytree. Prunable leaves carry a
  {0,1} array of the leaf's shape; non-prunable leaves carry a scalar 1.0
  (broadcasts in ``apply_masks`` at zero memory cost).
* **Prunable leaves** are the ≥2-D linear weights of each block (attention
  projections, MLP/expert matrices, Mamba in/out projections and conv).
  Routers, norms, biases, embeddings, LM head, and SSD dynamics (A_log, D,
  dt_bias) are never pruned (DESIGN.md §5).
* Every prunable leaf has a **canonical (reduction, out) 2-D view** via
  ``to_matrix`` — pruning scores, N:M groups, and SparseGPT Hessians all
  operate in this view; ``from_matrix`` restores the leaf shape. N:M groups
  run along the *reduction* axis (the dim a sparse-tensor-core / our
  nm_spmm kernel would exploit).
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Any

# leaf names that are prunable (last path component)
PRUNABLE_NAMES = frozenset(
    {
        "wq", "wk", "wv", "wo",                  # attention projections
        "w_up", "w_gate", "w_down",              # MLP / expert FFNs
        "in_z", "in_x", "in_B", "in_C", "in_dt", # Mamba2 in-projections
        "out", "conv_w",                         # Mamba2 out-proj / dw-conv
    }
)
# path components that veto pruning wherever they appear
PROTECTED_PARENTS = frozenset({"router", "embed", "head", "gnorm"})


def _path_names(path) -> Tuple[str, ...]:
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(str(p.key))
        elif hasattr(p, "name"):
            out.append(str(p.name))
        else:
            out.append(str(p))
    return tuple(out)


def is_prunable(path, leaf) -> bool:
    names = _path_names(path)
    if not names or names[-1] not in PRUNABLE_NAMES:
        return False
    if any(n in PROTECTED_PARENTS for n in names):
        return False
    return getattr(leaf, "ndim", 0) >= 2


def map_prunable(fn: Callable, params: Params, *rest) -> Params:
    """tree_map over prunable leaves only; others pass through unchanged
    (from ``params``). ``fn(name, leaf, *rest_leaves)``."""

    def g(path, leaf, *r):
        if is_prunable(path, leaf):
            return fn(_path_names(path)[-1], leaf, *r)
        return leaf

    return jax.tree_util.tree_map_with_path(g, params, *rest)


def ones_masks(params: Params) -> Params:
    """All-dense masks: prunable leaves get full ones, others scalar 1."""

    def g(path, leaf):
        if is_prunable(path, leaf):
            return jnp.ones(leaf.shape, jnp.float32)
        return jnp.ones((), jnp.float32)

    return jax.tree_util.tree_map_with_path(g, params)


def apply_masks(params: Params, masks: Params) -> Params:
    return jax.tree.map(lambda p, m: (p * m.astype(p.dtype)), params, masks)


def mask_gradients(grads: Params, masks: Params) -> Params:
    """Subgradient of W̄ = M ⊙ W: zero the gradient on pruned slots."""
    return jax.tree.map(lambda g, m: g * m.astype(g.dtype), grads, masks)


def sparsity_of(masks: Params, params: Params) -> float:
    """Fraction of *prunable* weights that are zeroed."""
    kept = total = 0.0

    def g(path, leaf, m):
        nonlocal kept, total
        if is_prunable(path, leaf):
            kept += float(jnp.sum(m))
            total += float(m.size)
        return leaf

    jax.tree_util.tree_map_with_path(g, params, masks)
    return 1.0 - kept / max(total, 1.0)


# ---------------------------------------------------------------------------
# canonical (reduction, out) 2-D views
# ---------------------------------------------------------------------------
# name -> number of leading axes that are reduction axes (after any expert
# batch axis). The remaining trailing axes are output axes.
_REDUCTION_LEAD = {
    "wq": 1, "wk": 1, "wv": 1,   # (d | H, hd)
    "wo": 2,                      # (H, hd | d)
    "w_up": 1, "w_gate": 1,       # (d | ff)
    "w_down": 1,                  # (ff | d)
    "in_z": 1, "in_x": 1, "in_B": 1, "in_C": 1, "in_dt": 1,  # (d | ...)
    "out": 2,                     # (H, P | d)
    "conv_w": 1,                  # (K | ch)  depthwise conv taps
}


def reduction_axes(name: str, ndim: int, batched: bool) -> int:
    return _REDUCTION_LEAD[name]


def is_expert_batched(name: str, leaf: jax.Array) -> bool:
    """Expert leaves carry a leading E axis: (E, d, ff) / (E, ff, d)."""
    return name in ("w_up", "w_gate", "w_down") and leaf.ndim == 3


def to_matrix(name: str, leaf: jax.Array) -> Tuple[jax.Array, Tuple]:
    """Leaf -> (R, O) matrix (or (E, R, O) for expert leaves) + shape tag."""
    if is_expert_batched(name, leaf):
        return leaf, ("expert", leaf.shape)
    lead = _REDUCTION_LEAD[name]
    r = 1
    for s in leaf.shape[:lead]:
        r *= s
    o = 1
    for s in leaf.shape[lead:]:
        o *= s
    return leaf.reshape(r, o), ("flat", leaf.shape)


def from_matrix(mat: jax.Array, tag: Tuple) -> jax.Array:
    kind, shape = tag
    return mat.reshape(shape)


# logical (unstacked) rank per prunable leaf name — anything beyond these
# dims is a stack axis (L layers, (G,K) hybrid groups, E experts...)
_LOGICAL_NDIM = {
    "wq": 3, "wk": 3, "wv": 3, "wo": 3,
    "w_up": 2, "w_gate": 2, "w_down": 2,
    "in_z": 3, "in_x": 3, "in_B": 2, "in_C": 2, "in_dt": 2,
    "out": 3, "conv_w": 2,
}


def to_matrix_stacked(name: str, leaf: jax.Array) -> Tuple[jax.Array, Tuple]:
    """Like ``to_matrix`` but tolerates leading stack axes (whole-tree
    consumers like magnitude pruning see (L, ...) / (L, E, ...) leaves):
    returns (S..., R, O) with all stack dims preserved up front."""
    n_log = _LOGICAL_NDIM[name]
    lead = _REDUCTION_LEAD[name]
    stack = leaf.shape[: leaf.ndim - n_log]
    logical = leaf.shape[leaf.ndim - n_log:]
    r = 1
    for s in logical[:lead]:
        r *= s
    o = 1
    for s in logical[lead:]:
        o *= s
    return leaf.reshape(*stack, r, o), ("stacked", leaf.shape)


# ---------------------------------------------------------------------------
# mask construction from scores
# ---------------------------------------------------------------------------
def topk_mask_rows(scores: jax.Array, sparsity: float) -> jax.Array:
    """Per-output-column unstructured mask: for each column of the (R, O)
    score matrix keep the top (1-sparsity) fraction along the reduction
    axis (Wanda's per-output comparison group)."""
    R = scores.shape[-2]
    keep = max(1, int(round(R * (1.0 - sparsity))))
    # rank along reduction axis
    idx = jnp.argsort(jnp.argsort(-scores, axis=-2), axis=-2)  # 0 = biggest
    return (idx < keep).astype(jnp.float32)


def global_topk_mask(scores: jax.Array, sparsity: float) -> jax.Array:
    """Per-matrix top-k mask (magnitude pruning's comparison group). With
    leading stack dims (..., R, O) the threshold is per stacked slice
    (= per-layer magnitude pruning)."""
    r, o = scores.shape[-2:]
    n = r * o
    keep = max(1, int(round(n * (1.0 - sparsity))))
    flat = scores.reshape(*scores.shape[:-2], n)
    thresh = jax.lax.top_k(flat, keep)[0][..., -1]
    return (scores >= thresh[..., None, None]).astype(jnp.float32)


def nm_mask(scores: jax.Array, n: int, m: int) -> jax.Array:
    """N:M mask along the reduction axis of an (..., R, O) score matrix:
    within every group of ``m`` consecutive reduction slots, keep the ``n``
    highest-scoring. R must be divisible by m (all assigned archs are)."""
    *lead, R, O = scores.shape
    assert R % m == 0, f"reduction dim {R} not divisible by M={m}"
    g = scores.reshape(*lead, R // m, m, O)
    rank = jnp.argsort(jnp.argsort(-g, axis=-2), axis=-2)
    return (rank < n).astype(jnp.float32).reshape(*lead, R, O)


# ---------------------------------------------------------------------------
# N:M compressed representation (for kernels/nm_spmm)
# ---------------------------------------------------------------------------
def nm_compress(w: jax.Array, mask: jax.Array, n: int, m: int):
    """Dense (R, O) weight + N:M mask -> (values (R//m*n, O), idx (R//m*n, O) int8).

    idx holds each kept slot's offset within its M-group (0..m-1) — the
    layout the nm_spmm Pallas kernel consumes (2-bit-packable; stored int8).
    """
    R, O = w.shape
    G = R // m
    wg = (w * mask).reshape(G, m, O)
    mg = mask.reshape(G, m, O)
    # order kept slots first within each group (stable by offset)
    order = jnp.argsort(-mg, axis=1, stable=True)  # kept (1) before dropped
    top = order[:, :n, :]  # (G, n, O) offsets of kept slots
    vals = jnp.take_along_axis(wg, top, axis=1)  # (G, n, O)
    return vals.reshape(G * n, O), top.astype(jnp.int8).reshape(G * n, O)


def nm_decompress(vals: jax.Array, idx: jax.Array, n: int, m: int) -> jax.Array:
    """Inverse of nm_compress -> dense (R, O)."""
    GN, O = vals.shape
    G = GN // n
    v = vals.reshape(G, n, O)
    ix = idx.reshape(G, n, O).astype(jnp.int32)
    dense = jnp.zeros((G, m, O), vals.dtype)
    gi = jnp.arange(G)[:, None, None]
    oi = jnp.arange(O)[None, None, :]
    dense = dense.at[gi, ix, oi].set(v)
    return dense.reshape(G * m, O)

"""Per-linear activation taps.

Wanda needs per-linear input column norms ‖X_j‖₂; SparseGPT needs the
per-linear Gram matrix H = X Xᵀ (over the reduction dim). Both are
*inputs to each linear inside a block*, which differ per layer (ln1(h) for
q/k/v, attention context for wo, the post-norm stream for the MLP, ...).

``linear_inputs(family)`` returns a function
    taps(bp, cfg, h, positions, **aux) -> {leaf_name: activation (T, R)}
that replays one block functionally (reusing the model-layer code so the
replay can never drift from the real forward) and returns, for every
prunable leaf name, the activation matrix whose reduction-axis statistics
the pruning methods consume. Expert leaves get the *dispatched* per-expert
activations (E, C, d) so expert-wise stats are exact.
"""
from __future__ import annotations

from typing import Any, Callable, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import moe as MOE
from repro.models import ssm as SSM

Params = Dict[str, Any]


def _flat(x: jax.Array) -> jax.Array:
    """(B, S, R) -> (B*S, R)"""
    return x.reshape(-1, x.shape[-1])


# ---------------------------------------------------------------------------
def _attn_taps(bp: Params, cfg: ModelConfig, h, positions, out: Dict[str, jax.Array]):
    """Taps for one attention sub-block. Returns the post-attn stream."""
    attn_in = L.apply_norm(bp["ln1"], h, cfg.norm)
    out["wq"] = out["wk"] = out["wv"] = _flat(attn_in)
    q, k, v = L.qkv_proj(bp["attn"], attn_in)
    hd = bp["attn"]["wq"].shape[-1]
    cos, sin = L.rope_table(positions, hd, cfg.rope_theta)
    q = L.apply_rope(q, cos, sin)
    k = L.apply_rope(k, cos, sin)
    o = L.attend(q, k, v, causal=True, impl=cfg.attn_impl, chunk=cfg.attn_chunk)
    out["wo"] = o.reshape(-1, o.shape[-2] * o.shape[-1])  # (T, H*hd)
    return h + L.out_proj(bp["attn"], o)


def _mlp_taps(p: Params, cfg: ModelConfig, x, out: Dict[str, jax.Array], act: str):
    out["w_up"] = _flat(x)
    if "w_gate" in p:
        out["w_gate"] = _flat(x)
    up = x @ p["w_up"]
    if act == "swiglu":
        hidden = jax.nn.silu(x @ p["w_gate"]) * up
    elif act == "sq_relu":
        hidden = jnp.square(jax.nn.relu(up))
    else:
        hidden = jax.nn.gelu(up)
    out["w_down"] = _flat(hidden)
    return hidden @ p["w_down"]


# ---------------------------------------------------------------------------
def dense_taps(bp, cfg, h, positions, **aux):
    out: Dict[str, jax.Array] = {}
    h = _attn_taps(bp, cfg, h, positions, out)
    mlp_in = L.apply_norm(bp["ln2"], h, cfg.norm)
    _mlp_taps(bp["mlp"], cfg, mlp_in, out, cfg.mlp_act)
    return out


def moe_taps(bp, cfg, h, positions, **aux):
    if "moe" not in bp:  # leading dense block of a MoE stack
        return dense_taps(bp, cfg, h, positions)
    out: Dict[str, jax.Array] = {}
    h = _attn_taps(bp, cfg, h, positions, out)
    mlp_in = L.apply_norm(bp["ln2"], h, cfg.norm)
    xf = _flat(mlp_in)
    # replay routing to get per-expert dispatched inputs (E, C, d)
    p = bp["moe"]
    gates, idx, _ = MOE.route(p["router"]["w"], xf, cfg.moe_top_k)
    E, k = cfg.moe_num_experts, cfg.moe_top_k
    T_ = xf.shape[0]
    C = max(1, int(cfg.moe_capacity_factor * T_ * k / E))
    flat_idx = idx.reshape(-1)
    onehot = jax.nn.one_hot(flat_idx, E, dtype=jnp.int32)
    pos = jnp.cumsum(onehot, axis=0) - 1
    pos = jnp.take_along_axis(pos, flat_idx[:, None], axis=1)[:, 0]
    keep = pos < C
    pos_c = jnp.where(keep, pos, 0)
    x_rep = jnp.repeat(xf, k, axis=0)
    disp = jnp.zeros((E, C, xf.shape[-1]), xf.dtype)
    disp = disp.at[flat_idx, pos_c].add(
        jnp.where(keep[:, None], x_rep, 0).astype(xf.dtype), mode="drop"
    )
    out["w_up"] = out["w_gate"] = disp  # (E, C, d) expert-batched
    ew = p["experts"]
    hidden = jax.nn.silu(jnp.einsum("ecd,edf->ecf", disp, ew["w_gate"])) * jnp.einsum(
        "ecd,edf->ecf", disp, ew["w_up"]
    )
    out["w_down"] = hidden  # (E, C, ff)
    if "shared" in p:
        out["shared/w_up"] = out["shared/w_gate"] = xf
        sh = jax.nn.silu(xf @ p["shared"]["w_gate"]) * (xf @ p["shared"]["w_up"])
        out["shared/w_down"] = sh
    return out


def ssm_taps(bp, cfg, h, positions=None, **aux):
    out: Dict[str, jax.Array] = {}
    Bsz, S, d = h.shape
    H, P, N = cfg.ssm_num_heads, cfg.ssm_head_dim, cfg.ssm_state
    u = L.apply_norm(bp["ln"], h, cfg.norm)
    out["in_z"] = out["in_x"] = out["in_B"] = out["in_C"] = out["in_dt"] = _flat(u)
    z = jnp.einsum("bsd,dhp->bshp", u, bp["in_z"])
    x = jnp.einsum("bsd,dhp->bshp", u, bp["in_x"])
    Bm = u @ bp["in_B"]
    Cm = u @ bp["in_C"]
    dt_raw = jnp.einsum("bsd,dh->bsh", u, bp["in_dt"])
    xbc = jnp.concatenate([x.reshape(Bsz, S, H * P), Bm, Cm], axis=-1)
    out["conv_w"] = _flat(xbc)  # (T, ch): conv taps share channel stats
    xbc, _ = SSM.causal_conv(xbc, bp["conv_w"], bp["conv_b"])
    x = xbc[..., : H * P].reshape(Bsz, S, H, P)
    Bm = xbc[..., H * P : H * P + N]
    Cm = xbc[..., H * P + N :]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + bp["dt_bias"])
    A = -jnp.exp(bp["A_log"])
    y, _ = SSM.ssd_chunked(x, dt, A, Bm, Cm, cfg.ssm_chunk)
    y = y + bp["D"].astype(y.dtype)[None, None, :, None] * x
    yf = y.reshape(Bsz, S, H * P) * jax.nn.silu(z.reshape(Bsz, S, H * P))
    yf = L.rms_norm(yf, bp["gnorm"]["w"])
    out["out"] = yf  # (B, S, H*P) -> flattened below
    out["out"] = _flat(yf)
    return out


def encdec_dec_taps(bp, cfg, h, positions, memory=None, **aux):
    out: Dict[str, jax.Array] = {}
    h = _attn_taps(bp, cfg, h, positions, out)
    # cross attention
    x_in = L.apply_norm(bp["ln_x"], h, cfg.norm)
    out["xattn/wq"] = _flat(x_in)
    out["xattn/wk"] = out["xattn/wv"] = _flat(memory)
    q, _, _ = L.qkv_proj(bp["xattn"], x_in)
    mk = jnp.einsum("bsd,dhk->bshk", memory, bp["xattn"]["wk"])
    mv = jnp.einsum("bsd,dhk->bshk", memory, bp["xattn"]["wv"])
    o = L.attend(q, mk, mv, causal=False, impl=cfg.attn_impl, chunk=cfg.attn_chunk)
    out["xattn/wo"] = o.reshape(-1, o.shape[-2] * o.shape[-1])
    h = h + L.out_proj(bp["xattn"], o)
    mlp_in = L.apply_norm(bp["ln2"], h, cfg.norm)
    _mlp_taps(bp["mlp"], cfg, mlp_in, out, cfg.mlp_act)
    return out


def encdec_enc_taps(bp, cfg, h, positions, **aux):
    out: Dict[str, jax.Array] = {}
    attn_in = L.apply_norm(bp["ln1"], h, cfg.norm)
    out["wq"] = out["wk"] = out["wv"] = _flat(attn_in)
    q, k, v = L.qkv_proj(bp["attn"], attn_in)
    o = L.attend(q, k, v, causal=False, impl=cfg.attn_impl, chunk=cfg.attn_chunk)
    out["wo"] = o.reshape(-1, o.shape[-2] * o.shape[-1])
    h = h + L.out_proj(bp["attn"], o)
    mlp_in = L.apply_norm(bp["ln2"], h, cfg.norm)
    _mlp_taps(bp["mlp"], cfg, mlp_in, out, cfg.mlp_act)
    return out


def taps_for_block(cfg: ModelConfig, block_index: int, num_blocks: int) -> Callable:
    """Dispatch: which tap function applies to block ``block_index``."""
    fam = cfg.family
    if fam in ("dense", "vlm"):
        return dense_taps
    if fam == "moe":
        return moe_taps
    if fam == "ssm":
        return ssm_taps
    if fam == "hybrid":
        # last index is the shared attention block (model.py convention)
        if block_index == num_blocks - 1:
            return dense_taps
        return ssm_taps
    if fam == "encdec":
        if block_index < cfg.enc_layers:
            return encdec_enc_taps
        return encdec_dec_taps
    raise ValueError(fam)

"""Unified pruning driver: ``prune(model, params, calib, method, ...)``.

Produces (masks, pruned_params) for any of the five methods. The stream
walk follows the official Wanda/SparseGPT convention (inputs propagate
through already-pruned blocks); magnitude needs no data; FLAP does a
two-pass walk (scores first — they're ranked globally — then masks).

Masks here are *full* pytrees (ones for every leaf, 0/1 arrays on pruned
leaves) so the model's own get_block/set_block slice them like params.
``pruned_params`` always stores masked weights (zeros at pruned slots):
the invariant EBFT, serving, and the N:M compressor rely on.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.pruning import common as C
from repro.core.pruning import dsnot as DSNOT
from repro.core.pruning import flap as FLAP
from repro.core.pruning import magnitude as MAG
from repro.core.pruning import sparsegpt as SGPT
from repro.core.pruning import wanda as WANDA
from repro.sparsity import sparse_params as SP

Params = Any


def full_ones_masks(params: Params) -> Params:
    return jax.tree.map(lambda p: jnp.ones(p.shape, jnp.float32), params)


def _set_path(tree, names, value):
    """Functional set of a nested-dict path."""
    if len(names) == 1:
        return {**tree, names[0]: value}
    return {**tree, names[0]: _set_path(tree[names[0]], names[1:], value)}


def _get_path(tree, names):
    for n in names:
        tree = tree[n]
    return tree


def prune(
    model,
    params: Params,
    calib: Optional[np.ndarray],
    method: str = "wanda",
    sparsity: float = 0.5,
    pattern: Optional[Tuple[int, int]] = None,
    microbatch: int = 8,
    extra_batch: Optional[Dict[str, np.ndarray]] = None,
    dsnot_init: str = "wanda",
    dsnot_cycles: int = 30,
) -> Tuple[Params, Params]:
    """Returns (masks, pruned_params). ``method`` ∈ {magnitude, wanda,
    sparsegpt, dsnot, flap}. ``pattern``=(n, m) for N:M sparsity."""
    if method == "magnitude":
        masks = expand_masks(
            params, MAG.make_masks(params, sparsity, pattern)
        )
        return masks, SP.apply_masks(params, masks)

    if method == "flap":
        return _prune_flap(model, params, calib, sparsity, microbatch, extra_batch)

    if method == "dsnot":
        init_masks, _ = prune(
            model, params, calib, dsnot_init, sparsity, pattern, microbatch,
            extra_batch,
        )
        return _dsnot_walk(
            model, params, init_masks, calib, microbatch, extra_batch,
            dsnot_cycles, pattern,
        )

    assert method in ("wanda", "sparsegpt"), method
    want_h = method == "sparsegpt"
    masks = full_ones_masks(params)

    def visit(i, bp, ctx):
        nonlocal masks
        stats = C.collect_block_stats(
            model, bp, i, ctx["h_mb"], ctx["pos_mb"], ctx["aux_mb"],
            want_hessian=want_h,
        )
        mask_bp = model.get_block(masks, i)
        new_bp = bp

        def g(path, leaf):
            nonlocal mask_bp, new_bp
            if not SP.is_prunable(path, leaf):
                return leaf
            names = SP._path_names(path)
            st = C.stats_for_leaf(stats, names)
            if method == "wanda":
                mk = WANDA.leaf_mask(names[-1], leaf, st, sparsity, pattern)
                nw = leaf * mk.astype(leaf.dtype)
            else:
                nw, mk = SGPT.leaf_prune(names[-1], leaf, st, sparsity, pattern)
                nw = nw.astype(leaf.dtype)
            mask_bp = _set_path(mask_bp, names, mk)
            new_bp = _set_path(new_bp, names, nw)
            return leaf

        jax.tree_util.tree_map_with_path(g, bp)
        masks = model.set_block(masks, i, mask_bp)
        return new_bp

    pruned = C.walk_blocks(
        model, params, calib, visit, microbatch, extra_batch,
        params_student=jax.tree.map(lambda x: x, params),
    )
    return masks, pruned


# ---------------------------------------------------------------------------
def _dsnot_walk(model, params, init_masks, calib, microbatch, extra_batch, cycles, pattern):
    masks = init_masks

    def visit(i, bp, ctx):
        nonlocal masks
        stats = C.collect_block_stats(
            model, bp, i, ctx["h_mb"], ctx["pos_mb"], ctx["aux_mb"],
            want_hessian=False,
        )
        mask_bp = model.get_block(masks, i)
        dense_bp = model.get_block(params, i)
        new_bp = bp

        def g(path, leaf):
            nonlocal mask_bp, new_bp
            if not SP.is_prunable(path, leaf):
                return leaf
            names = SP._path_names(path)
            st = C.stats_for_leaf(stats, names)
            mk_old = _get_path(mask_bp, names)
            dense_leaf = _get_path(dense_bp, names)
            mk = DSNOT.leaf_reselect(names[-1], dense_leaf, mk_old, st, cycles, pattern)
            mask_bp = _set_path(mask_bp, names, mk)
            new_bp = _set_path(new_bp, names, dense_leaf * mk.astype(leaf.dtype))
            return leaf

        jax.tree_util.tree_map_with_path(g, bp)
        masks = model.set_block(masks, i, mask_bp)
        return new_bp

    pruned = C.walk_blocks(
        model, params, calib, visit, microbatch, extra_batch,
        params_student=SP.apply_masks(params, init_masks),
    )
    return masks, pruned


# ---------------------------------------------------------------------------
def _prune_flap(model, params, calib, sparsity, microbatch, extra_batch):
    cfg = model.cfg
    assert cfg.family in ("dense", "vlm"), "FLAP targets attention+MLP stacks"
    scores = []

    def score_visit(i, bp, ctx):
        stats = C.collect_block_stats(
            model, bp, i, ctx["h_mb"], ctx["pos_mb"], ctx["aux_mb"],
            want_hessian=False,
        )
        scores.append(FLAP.block_unit_scores(bp, stats, cfg))
        return None  # pass 1: dense stream, no modification

    C.walk_blocks(model, params, calib, score_visit, microbatch, extra_batch)
    unit_masks = FLAP.global_structured_masks(scores, sparsity)

    masks = full_ones_masks(params)
    for i, unit in enumerate(unit_masks):
        bp = model.get_block(params, i)
        mask_bp = model.get_block(masks, i)
        mask_bp = FLAP.expand_block_masks(bp, unit, mask_bp)
        masks = model.set_block(masks, i, mask_bp)
    return masks, SP.apply_masks(params, masks)


# ---------------------------------------------------------------------------
def expand_masks(params: Params, masks: Params) -> Params:
    """Scalar-placeholder masks -> full arrays (so block slicing works)."""

    def g(path, m, p):
        if getattr(m, "ndim", 0) == 0:
            return jnp.ones(p.shape, jnp.float32) * m
        return m

    return jax.tree_util.tree_map_with_path(g, masks, params)

"""Block-wise reconstruction machinery (paper Eq. 3/4).

The EBFT objective for block l is

    min_{W̄_l}  || z^l  −  z̄^l ||₂²        (Eq. 4)

where z^l is the *dense teacher's* block-l output and z̄^l is the sparse
student's block-l output computed from the student's own stream z̄^{l-1}
(Eq. 3 — the sparse stream propagates, so earlier blocks' residual error
is visible to later blocks and gets compensated).

This module provides:
  * ``execution_plan`` — the per-family visit order (which block runs when,
    including Zamba2's shared block appearing at G sites and Seamless's
    encoder→decoder segmentation);
  * ``block_loss`` — the Eq.4 loss for one block given (masked) weights;
  * stream-advance helpers shared by EBFT, mask-tuning, and the pruning
    drivers (they all walk the same teacher stream).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.sparsity.sparse_params import apply_masks

Params = Any


@dataclasses.dataclass
class Segment:
    """A contiguous stretch of the model sharing one hidden stream."""

    visits: List[Tuple[int, int]]  # (block_index, site_id) in execution order
    h0: Callable[[Params, Dict], Tuple[jax.Array, jax.Array]]  # -> (h, positions)
    aux: Callable[[Params, Dict], Dict[str, jax.Array]]  # e.g. encoder memory


def execution_plan(model) -> List[Segment]:
    cfg = model.cfg
    fam = cfg.family

    def default_h0(params, batch):
        return model.embed_tokens(params, batch)

    no_aux = lambda params, batch: {}

    if fam == "hybrid":
        # mamba blocks interleaved with the shared attention block (index
        # num_blocks-1) at every hybrid_attn_every layers; trailing mambas.
        K = cfg.hybrid_attn_every
        G = cfg.num_layers // K
        shared = model.num_blocks - 1
        visits: List[Tuple[int, int]] = []
        for g in range(G):
            visits += [(g * K + j, 0) for j in range(K)]
            visits.append((shared, g))
        visits += [(i, 0) for i in range(G * K, cfg.num_layers)]
        return [Segment(visits, default_h0, no_aux)]

    if fam == "encdec":
        from repro.models import encdec as ED

        n_enc = cfg.enc_layers

        def enc_h0(params, batch):
            frames = batch["frames"].astype(jnp.dtype(cfg.dtype))
            return frames, jnp.arange(frames.shape[1])[None, :]

        def dec_aux(params, batch):
            # memory from *this* param set: teacher uses dense encoder,
            # student uses its (already fine-tuned) sparse encoder.
            return {"memory": ED.encode(params, cfg, batch["frames"])}

        enc = Segment([(i, 0) for i in range(n_enc)], enc_h0, no_aux)
        dec = Segment(
            [(i, 0) for i in range(n_enc, model.num_blocks)], default_h0, dec_aux
        )
        return [enc, dec]

    return [Segment([(i, 0) for i in range(model.num_blocks)], default_h0, no_aux)]


# ---------------------------------------------------------------------------
def block_kind(model, i: int) -> str:
    """Blocks of the same kind share one compiled tune/advance step —
    apply_block's behaviour depends only on the kind, never on i itself."""
    cfg = model.cfg
    if cfg.family == "moe":
        return "dense" if i < cfg.moe_first_dense else "moe"
    if cfg.family == "hybrid":
        return "shared" if i == model.num_blocks - 1 else "mamba"
    if cfg.family == "encdec":
        return "enc" if i < cfg.enc_layers else "dec"
    return "block"


def advance(model, params, i: int, h: jax.Array, positions, aux: Dict) -> jax.Array:
    """Apply block ``i`` with its own stored weights."""
    bp = model.get_block(params, i)
    return model.apply_block(params, i, bp, h, positions, **aux)


def advance_with(model, params, i: int, bp, h, positions, aux: Dict) -> jax.Array:
    """Apply block ``i`` with explicit block weights ``bp``."""
    return model.apply_block(params, i, bp, h, positions, **aux)


def block_loss(
    model, i: int, bw: Params, masks_b: Params, h_in, target, positions, aux: Dict
) -> jax.Array:
    """Eq. 4: mean-squared block-output reconstruction error for block i.

    ``bw`` are the block's trainable weights; ``masks_b`` the block's frozen
    masks (W̄ = M ⊙ W). Mean (not sum) keeps lr scale-free across shapes.
    """
    out = model.apply_block(None, i, apply_masks(bw, masks_b), h_in, positions, **aux)
    err = (out - target).astype(jnp.float32)
    return jnp.mean(jnp.square(err))


def reconstruction_error(model, i, bw, masks_b, h_in, target, positions, aux) -> jax.Array:
    """Reported metric: relative block error ‖z−z̄‖₂ / ‖z‖₂."""
    out = model.apply_block(None, i, apply_masks(bw, masks_b), h_in, positions, **aux)
    num = jnp.linalg.norm((out - target).astype(jnp.float32))
    den = jnp.maximum(jnp.linalg.norm(target.astype(jnp.float32)), 1e-9)
    return num / den

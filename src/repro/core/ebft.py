"""EBFT: block-wise fine-tuning of sparse LLMs (the paper's contribution).

Algorithm 1, faithfully:

    for block l = 1..L:
        E ← block-wise reconstruction error (Eq. 4) over D_c
        repeat up to T epochs, early-stopping when E converges:
            W̄ₗ ← W̄ₗ − α · ∇_{W̄ₗ} E          (backprop through the block)
        advance the sparse stream with the tuned block

Paper hyper-parameters: D_c = 256×1024-token C4 segments, T = 10 epochs,
α = 2e-4 (Adam). Masks are frozen throughout — only surviving weights
move; the mask is applied *inside* the loss (W̄ = M ⊙ W), so pruned slots
get exactly zero gradient by the chain rule.

Streaming property (the paper's 16 GB claim): only one block's weights +
optimizer moments are live at a time; the teacher/student streams advance
microbatch-wise. The walk realizes the DESIGN.md §3 pipelining: block
l+1's teacher stream is dispatched while block l fine-tunes
(core/pruning/common.py, ``TeacherPrefetcher``).

The per-block tuning loop itself is FUSED (``fused_epochs``, default on):
each block's microbatches are stacked along a leading axis and the whole
epoch budget runs as one jitted ``lax.scan`` over epochs (inner scan over
microbatches), with the plateau early-stop evaluated on device
(``plateau_early_stop_device``) via ``lax.cond`` — converged blocks skip
their remaining epochs without a host round-trip. Block weights are
DONATED into the fused call, so weights and Adam moments update in place
and the measured ``live_block_bytes`` stays one-block-sized. The host
syncs once per block (one ``device_get`` of scalars + the loss history)
instead of once per microbatch-step: ≤ 3 tune-path dispatches and 1 host
sync per block, vs. epochs × microbatches + 2 × microbatches before
(docs/PERF.md has the accounting). Ragged microbatch shapes fall back to
the legacy per-step loop.

Zamba2's shared attention block (one weight set, G invocation sites) is
fine-tuned once on the *sum* of its per-site reconstruction errors
(DESIGN.md §5): site data is collected during the walk and the shared
block is tuned on the union afterwards.

Mesh-aware mode (docs/DISTRIBUTED.md): when ``EBFTConfig.mesh_plan`` is
an active :class:`~repro.distributed.meshplan.MeshPlan`, the stacked
calibration microbatches are sharded over the mesh's batch axes and the
live block's weights/masks (and, by inheritance inside the donated
dispatch, its Adam moments) over ``"model"``; the fused scan then runs
SPMD — GSPMD inserts the psum gradient all-reduce across the data axes —
while the one-live-block-per-device memory property *improves* to
one-live-block-SHARD per device. Single-device behavior (``mesh_plan``
None/inactive) is bit-for-bit unchanged, and ragged shapes still fall
back to the unsharded legacy loop.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import reconstruction as R
from repro.core.pruning import common as C
from repro.obs import metrics as OM
from repro.obs import trace as OT
from repro.obs.profile import (
    DispatchLedger, FirstCallTimer, ebft_live_block_bytes, live_bytes,
)
from repro.optim.optimizers import adam, apply_updates
from repro.optim.schedules import plateau_early_stop, plateau_early_stop_device
from repro.sparsity.sparse_params import apply_masks

Params = Any


@dataclasses.dataclass
class EBFTConfig:
    lr: float = 2e-4
    epochs: int = 10          # paper: T = 10
    microbatch: int = 8
    patience: int = 2         # early stop when loss plateaus (paper: "converged")
    rel_tol: float = 1e-3
    seed: int = 0
    fused_epochs: bool = True  # one scanned+donated dispatch per block
    prefetch_depth: int = 1    # teacher stream dispatched this many blocks ahead
    mesh_plan: Optional[Any] = None  # MeshPlan; None/inactive = single device


@dataclasses.dataclass
class BlockReport:
    index: int
    kind: str
    epochs_run: int
    loss_before: float
    loss_after: float
    early_stop: str = "max_epochs"   # "plateau" | "max_epochs"
    history: List[float] = dataclasses.field(default_factory=list)
    live_bytes: int = 0              # weights + masks + f32 Adam moments
    path: str = "fused"              # "fused" | "legacy"
    dispatches: int = 0              # tune-path device dispatches for this block
    host_syncs: int = 0              # tune-path device→host syncs for this block
    device_dispatches: int = 0       # dispatches x participating devices
    live_bytes_per_shard: int = 0    # live_bytes per device under the MeshPlan
    collective_bytes: int = 0        # analytic grad all-reduce wire bytes

    def asdict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)


# ---------------------------------------------------------------------------
def _make_tune_step(model, kind_rep_i: int, ecfg: EBFTConfig):
    """Per-block-kind executables (same shapes ⇒ same executable for every
    layer of the kind): the legacy per-microbatch ``step``/``eval_loss``
    pair and the fused whole-block ``fused_run``."""
    opt = adam(ecfg.lr)

    def loss_fn(bw, mask_bp, h, target, pos, aux):
        return R.block_loss(model, kind_rep_i, bw, mask_bp, h, target, pos, aux)

    vg = jax.value_and_grad(loss_fn)

    @jax.jit
    def step(bw, opt_state, mask_bp, h, target, pos, aux):
        loss, g = vg(bw, mask_bp, h, target, pos, aux)
        upd, opt_state = opt.update(g, opt_state, bw)
        return apply_updates(bw, upd), opt_state, loss

    @jax.jit
    def eval_loss(bw, mask_bp, h, target, pos, aux):
        return loss_fn(bw, mask_bp, h, target, pos, aux)

    # -- the fused path: whole tuning loop in one donated dispatch ---------
    E, patience, rel_tol = ecfg.epochs, ecfg.patience, ecfg.rel_tol

    def fused_run(bw, mask_bp, h_st, target_st, pos_st, aux_st):
        data = (h_st, target_st, pos_st, aux_st)
        n_mb = h_st.shape[0]

        def eval_mean(bw_):
            def body(acc, mb):
                h, t, p, a = mb
                return acc + loss_fn(bw_, mask_bp, h, t, p, a), None

            tot, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), data)
            return tot / n_mb

        before = eval_mean(bw)
        opt_state = opt.init(bw)
        hist = jnp.full((E + 1,), jnp.inf, jnp.float32).at[0].set(before)

        def mb_step(carry, mb):
            bw_, opt_state_ = carry
            h, t, p, a = mb
            loss, g = vg(bw_, mask_bp, h, t, p, a)
            upd, opt_state_ = opt.update(g, opt_state_, bw_)
            return (apply_updates(bw_, upd), opt_state_), loss

        def epoch_body(carry, e):
            bw_, opt_state_, hist_, n_run, plateaued = carry

            def live(operand):
                bw_, opt_state_, hist_, n_run = operand
                (bw_, opt_state_), losses = jax.lax.scan(
                    mb_step, (bw_, opt_state_), data
                )
                mean = jnp.mean(losses)
                hist_ = hist_.at[e + 1].set(mean)
                n_run = n_run + 1
                stop = plateau_early_stop_device(
                    hist_, n_run + 1, patience, rel_tol
                )
                return bw_, opt_state_, hist_, n_run, stop

            def skip(operand):
                bw_, opt_state_, hist_, n_run = operand
                return bw_, opt_state_, hist_, n_run, jnp.asarray(True)

            out = jax.lax.cond(
                plateaued, skip, live, (bw_, opt_state_, hist_, n_run)
            )
            return out, None

        init = (bw, opt_state, hist, jnp.zeros((), jnp.int32),
                jnp.asarray(False))
        (bw, _, hist, n_run, plateaued), _ = jax.lax.scan(
            epoch_body, init, jnp.arange(E)
        )
        after = eval_mean(bw)
        bw = apply_masks(bw, mask_bp)
        return bw, before, after, hist, n_run, plateaued

    # donate bw: weights + (internal) Adam moments update in place, so the
    # live-block footprint stays at one block (the paper's 16 GB property)
    fused = jax.jit(fused_run, donate_argnums=(0,))
    # first-call (trace+compile) wall time books onto the compile clock,
    # which the walk drains per phase — so the walk/tune histogram shows
    # steady-state and the one-compile-per-block-kind cost lands in
    # ebft/walk/tune_compile_s (docs/PERF.md)
    return opt, FirstCallTimer(step), FirstCallTimer(eval_loss), \
        FirstCallTimer(fused)


def _stack_microbatches(data: List[Tuple]):
    """[(h, target, pos, aux), ...] -> one stacked pytree tuple with a
    leading microbatch axis, or None when shapes are ragged (the fused
    scan needs a uniform leading axis)."""
    if not data:
        return None
    leaves0, treedef0 = jax.tree.flatten(data[0])
    sig0 = [(jnp.shape(x), jnp.result_type(x)) for x in leaves0]
    for mb in data[1:]:
        leaves, treedef = jax.tree.flatten(mb)
        if treedef != treedef0 \
                or [(jnp.shape(x), jnp.result_type(x)) for x in leaves] != sig0:
            return None
    return jax.tree.map(lambda *xs: jnp.stack(xs), *data)


def tune_block(
    model,
    i: int,
    bp: Params,
    mask_bp: Params,
    data: List[Tuple],  # [(h, target, pos, aux), ...] microbatches
    ecfg: EBFTConfig,
    step_cache: Dict,
    stacked: Optional[Tuple] = None,  # pre-stacked (h, target, pos, aux)
) -> Tuple[Params, BlockReport]:
    kind = R.block_kind(model, i)
    if kind not in step_cache:
        step_cache[kind] = _make_tune_step(model, i, ecfg)
    opt, step, eval_loss, fused = step_cache[kind]
    plan = ecfg.mesh_plan
    sharded = plan is not None and plan.active and ecfg.fused_epochs
    ledger = DispatchLedger(
        "ebft/tune", devices=plan.device_count if sharded else 1
    )

    with OT.span("ebft/block", index=i, kind=kind) as sp:
        if ecfg.fused_epochs and stacked is None:
            stacked = _stack_microbatches(data)
        if ecfg.fused_epochs and stacked is not None:
            if sharded:
                # block weights/masks over "model" (moments inherit inside
                # the donated dispatch), calibration batch over the data
                # axes; re-putting already-sharded walk streams is a no-op
                bp = plan.put_block(bp)
                mask_bp = plan.put_block(mask_bp)
                stacked = plan.put_stacked(stacked)
            bp, report = _tune_block_fused(
                i, kind, bp, mask_bp, stacked, fused, ledger
            )
            if sharded:
                # analytic wire accounting: one psum of the block's grads
                # per optimizer step (epochs x microbatches), ring cost
                n_mb = int(jax.tree.leaves(stacked)[0].shape[0])
                steps = report.epochs_run * n_mb
                report.collective_bytes = steps * plan.allreduce_bytes(
                    live_bytes(bp)
                )
        else:
            bp, report = _tune_block_legacy(
                i, kind, bp, mask_bp, data, ecfg, opt, step, eval_loss, ledger
            )
        report.device_dispatches = ledger.device_dispatches

        live = 0
        if OT.enabled():
            # the streaming claim, measured: only this block's weights,
            # masks, and Adam moments are optimizer-live right now
            live = ebft_live_block_bytes(bp, mask_bp)
            live_shard = live
            if sharded:
                moments = jax.tree.map(
                    lambda x: jax.ShapeDtypeStruct(np.shape(x), np.float32),
                    bp,
                )
                live_shard = (plan.sharded_bytes(bp)
                              + plan.sharded_bytes(mask_bp)
                              + 2 * plan.sharded_bytes(moments))
            report.live_bytes_per_shard = live_shard
            OM.gauge("ebft/live_block_bytes").set(live)  # summary max = peak
            OM.gauge("ebft/live_block_bytes_per_shard").set(live_shard)
            if report.collective_bytes:
                OM.counter("ebft/collective_bytes").inc(report.collective_bytes)
                OM.gauge("ebft/collective_bytes_per_block").set(
                    report.collective_bytes
                )
            OM.series("ebft/loss_before").append(report.loss_before, step=i)
            OM.series("ebft/loss_after").append(report.loss_after, step=i)
            OM.series("ebft/epochs_run").append(report.epochs_run, step=i)
            OM.series("ebft/dispatches_per_block").append(
                report.dispatches, step=i
            )
            OM.series("ebft/host_syncs_per_block").append(
                report.host_syncs, step=i
            )
            OM.counter(f"ebft/early_stop/{report.early_stop}").inc()
            sp.set(epochs=report.epochs_run, loss_before=report.loss_before,
                   loss_after=report.loss_after, early_stop=report.early_stop,
                   live_bytes=live, path=report.path,
                   dispatches=report.dispatches, host_syncs=report.host_syncs,
                   devices=ledger.devices)
        report.live_bytes = live
    return bp, report


def _tune_block_fused(
    i: int, kind: str, bp: Params, mask_bp: Params, stacked: Tuple,
    fused: Callable, ledger: DispatchLedger,
) -> Tuple[Params, BlockReport]:
    """One donated dispatch for the whole block; one host sync for the
    scalars + loss history."""
    h_st, target_st, pos_st, aux_st = stacked
    bp, before_d, after_d, hist_d, n_run_d, plateaued_d = fused(
        bp, mask_bp, h_st, target_st, pos_st, aux_st
    )
    ledger.dispatch()
    before, after, hist, epochs_run, plateaued = jax.device_get(
        (before_d, after_d, hist_d, n_run_d, plateaued_d)
    )
    ledger.host_sync()
    epochs_run = int(epochs_run)
    history = [float(v) for v in hist[: epochs_run + 1]]
    early_stop = "plateau" if bool(plateaued) else "max_epochs"
    return bp, BlockReport(
        i, kind, epochs_run, float(before), float(after), early_stop,
        history, 0, "fused", ledger.dispatches, ledger.host_syncs,
    )


def _tune_block_legacy(
    i: int, kind: str, bp: Params, mask_bp: Params, data: List[Tuple],
    ecfg: EBFTConfig, opt, step, eval_loss, ledger: DispatchLedger,
) -> Tuple[Params, BlockReport]:
    """Per-microbatch dispatch loop (ragged shapes / ``fused_epochs=False``).

    Still avoids per-microbatch host syncs: per-epoch means are reduced on
    device and transferred as one scalar (the plateau check is host-side
    here, so one sync per epoch is the floor)."""

    def eval_mean(bp_) -> float:
        losses = [eval_loss(bp_, mask_bp, *mb) for mb in data]
        ledger.dispatch(len(losses) + 1)
        ledger.host_sync()
        return float(jnp.mean(jnp.stack(losses)))  # obs: sync-ok (one scalar)

    before = eval_mean(bp)
    opt_state = opt.init(bp)
    history: List[float] = [before]
    epochs_run = 0
    early_stop = "max_epochs"
    for _ in range(ecfg.epochs):
        losses = []
        for mb in data:
            bp, opt_state, loss = step(bp, opt_state, mask_bp, *mb)
            losses.append(loss)
        ledger.dispatch(len(losses) + 1)
        ledger.host_sync()
        epochs_run += 1
        # obs: sync-ok (host-side plateau check needs the epoch mean)
        history.append(float(jnp.mean(jnp.stack(losses))))
        if plateau_early_stop(history, ecfg.patience, ecfg.rel_tol):
            early_stop = "plateau"
            break
    after = eval_mean(bp)
    bp = apply_masks(bp, mask_bp)
    ledger.dispatch()
    return bp, BlockReport(
        i, kind, epochs_run, before, after, early_stop, history, 0,
        "legacy", ledger.dispatches, ledger.host_syncs,
    )


# ---------------------------------------------------------------------------
def finetune(
    model,
    dense_params: Params,
    pruned_params: Params,
    masks: Params,
    calib: np.ndarray,
    ecfg: Optional[EBFTConfig] = None,
    extra_batch: Optional[Dict[str, np.ndarray]] = None,
    log: Optional[Callable[[str], None]] = None,
) -> Tuple[Params, List[BlockReport]]:
    """The EBFT driver. Returns (fine-tuned sparse params, per-block reports)."""
    ecfg = ecfg or EBFTConfig()
    plan = ecfg.mesh_plan
    mesh_devices = plan.device_count if plan is not None and plan.active else 1
    with OT.span("ebft/walk", epochs=ecfg.epochs, lr=ecfg.lr,
                 microbatch=ecfg.microbatch, fused=ecfg.fused_epochs,
                 prefetch_depth=ecfg.prefetch_depth,
                 mesh_devices=mesh_devices):
        student = apply_masks(pruned_params, masks)
        reports: List[BlockReport] = []
        step_cache: Dict = {}

        shared_idx = (
            model.num_blocks - 1 if model.cfg.family == "hybrid" else None
        )
        shared_sites: List[Tuple] = []

        def visit(i, bp, ctx):
            mask_bp = model.get_block(masks, i)
            data = list(
                zip(ctx["h_mb"], ctx["target_mb"], ctx["pos_mb"], ctx["aux_mb"])
            )
            if i == shared_idx:
                shared_sites.extend(data)  # tune once on the union (sum of sites)
                return None
            stacked = None
            if "h_st" in ctx:
                stacked = (ctx["h_st"], ctx["target_st"], ctx["pos_st"],
                           ctx["aux_st"])
            tuned, rep = tune_block(
                model, i, bp, mask_bp, data, ecfg, step_cache, stacked=stacked
            )
            reports.append(rep)
            if log:
                log(
                    f"block {i:3d} [{rep.kind}] epochs={rep.epochs_run} "
                    f"E: {rep.loss_before:.3e} -> {rep.loss_after:.3e}"
                )
            return tuned

        result = C.walk_blocks(
            model,
            dense_params,
            calib,
            visit,
            microbatch=ecfg.microbatch,
            extra_batch=extra_batch,
            params_student=student,
            dual_stream=True,
            prefetch_depth=ecfg.prefetch_depth,
            mesh_plan=ecfg.mesh_plan,
        )

        if shared_idx is not None and shared_sites:
            # the shared block is stored un-stacked (model.get_block returns
            # the leaves by reference, not a slice) — copy before the donated
            # fused call so `result`'s own buffers are never invalidated
            bp = jax.tree.map(jnp.copy, model.get_block(result, shared_idx))
            mask_bp = model.get_block(masks, shared_idx)
            tuned, rep = tune_block(
                model, shared_idx, bp, mask_bp, shared_sites, ecfg, step_cache
            )
            reports.append(rep)
            if log:
                log(
                    f"shared block [{rep.kind}] ({len(shared_sites)} site-batches) "
                    f"E: {rep.loss_before:.3e} -> {rep.loss_after:.3e}"
                )
            result = model.set_block(result, shared_idx, tuned)
    return result, reports

"""EBFT: block-wise fine-tuning of sparse LLMs (the paper's contribution).

Algorithm 1, faithfully:

    for block l = 1..L:
        E ← block-wise reconstruction error (Eq. 4) over D_c
        repeat up to T epochs, early-stopping when E converges:
            W̄ₗ ← W̄ₗ − α · ∇_{W̄ₗ} E          (backprop through the block)
        advance the sparse stream with the tuned block

Paper hyper-parameters: D_c = 256×1024-token C4 segments, T = 10 epochs,
α = 2e-4 (Adam). Masks are frozen throughout — only surviving weights
move; the mask is applied *inside* the loss (W̄ = M ⊙ W), so pruned slots
get exactly zero gradient by the chain rule.

Streaming property (the paper's 16 GB claim): only one block's weights +
optimizer moments are live at a time; the teacher/student streams advance
microbatch-wise. On the pod this block-locality becomes a pipelining
opportunity (DESIGN.md §3) — block l+1's teacher stream can be produced
while block l fine-tunes.

Zamba2's shared attention block (one weight set, G invocation sites) is
fine-tuned once on the *sum* of its per-site reconstruction errors
(DESIGN.md §5): site data is collected during the walk and the shared
block is tuned on the union afterwards.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import reconstruction as R
from repro.core.pruning import common as C
from repro.obs import metrics as OM
from repro.obs import trace as OT
from repro.obs.profile import ebft_live_block_bytes
from repro.optim.optimizers import adam, apply_updates
from repro.optim.schedules import plateau_early_stop
from repro.sparsity.sparse_params import apply_masks

Params = Any


@dataclasses.dataclass
class EBFTConfig:
    lr: float = 2e-4
    epochs: int = 10          # paper: T = 10
    microbatch: int = 8
    patience: int = 2         # early stop when loss plateaus (paper: "converged")
    rel_tol: float = 1e-3
    seed: int = 0


@dataclasses.dataclass
class BlockReport:
    index: int
    kind: str
    epochs_run: int
    loss_before: float
    loss_after: float
    early_stop: str = "max_epochs"   # "plateau" | "max_epochs"
    history: List[float] = dataclasses.field(default_factory=list)
    live_bytes: int = 0              # weights + masks + f32 Adam moments

    def asdict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)


# ---------------------------------------------------------------------------
def _make_tune_step(model, kind_rep_i: int, ecfg: EBFTConfig):
    """One Adam step on a block's weights against Eq. 4. Compiled once per
    block *kind* (same shapes ⇒ same executable for every layer)."""
    opt = adam(ecfg.lr)

    def loss_fn(bw, mask_bp, h, target, pos, aux):
        return R.block_loss(model, kind_rep_i, bw, mask_bp, h, target, pos, aux)

    vg = jax.value_and_grad(loss_fn)

    @jax.jit
    def step(bw, opt_state, mask_bp, h, target, pos, aux):
        loss, g = vg(bw, mask_bp, h, target, pos, aux)
        upd, opt_state = opt.update(g, opt_state, bw)
        return apply_updates(bw, upd), opt_state, loss

    @jax.jit
    def eval_loss(bw, mask_bp, h, target, pos, aux):
        return loss_fn(bw, mask_bp, h, target, pos, aux)

    return opt, step, eval_loss


def tune_block(
    model,
    i: int,
    bp: Params,
    mask_bp: Params,
    data: List[Tuple],  # [(h, target, pos, aux), ...] microbatches
    ecfg: EBFTConfig,
    step_cache: Dict,
) -> Tuple[Params, BlockReport]:
    kind = R.block_kind(model, i)
    if kind not in step_cache:
        step_cache[kind] = _make_tune_step(model, i, ecfg)
    opt, step, eval_loss = step_cache[kind]

    with OT.span("ebft/block", index=i, kind=kind) as sp:
        before = float(
            np.mean([float(eval_loss(bp, mask_bp, *mb)) for mb in data])
        )
        opt_state = opt.init(bp)
        history: List[float] = [before]
        epochs_run = 0
        early_stop = "max_epochs"
        for _ in range(ecfg.epochs):
            ep = 0.0
            for mb in data:
                bp, opt_state, loss = step(bp, opt_state, mask_bp, *mb)
                ep += float(loss)
            epochs_run += 1
            history.append(ep / max(len(data), 1))
            if plateau_early_stop(history, ecfg.patience, ecfg.rel_tol):
                early_stop = "plateau"
                break
        after = float(np.mean([float(eval_loss(bp, mask_bp, *mb)) for mb in data]))
        bp = apply_masks(bp, mask_bp)

        live = 0
        if OT.enabled():
            # the streaming claim, measured: only this block's weights,
            # masks, and Adam moments are optimizer-live right now
            live = ebft_live_block_bytes(bp, mask_bp)
            OM.gauge("ebft/live_block_bytes").set(live)  # summary max = peak
            OM.series("ebft/loss_before").append(before, step=i)
            OM.series("ebft/loss_after").append(after, step=i)
            OM.series("ebft/epochs_run").append(epochs_run, step=i)
            OM.counter(f"ebft/early_stop/{early_stop}").inc()
            sp.set(epochs=epochs_run, loss_before=before, loss_after=after,
                   early_stop=early_stop, live_bytes=live)
    return bp, BlockReport(i, kind, epochs_run, before, after,
                           early_stop, history, live)


# ---------------------------------------------------------------------------
def finetune(
    model,
    dense_params: Params,
    pruned_params: Params,
    masks: Params,
    calib: np.ndarray,
    ecfg: Optional[EBFTConfig] = None,
    extra_batch: Optional[Dict[str, np.ndarray]] = None,
    log: Optional[Callable[[str], None]] = None,
) -> Tuple[Params, List[BlockReport]]:
    """The EBFT driver. Returns (fine-tuned sparse params, per-block reports)."""
    ecfg = ecfg or EBFTConfig()
    with OT.span("ebft/walk", epochs=ecfg.epochs, lr=ecfg.lr,
                 microbatch=ecfg.microbatch):
        student = apply_masks(pruned_params, masks)
        reports: List[BlockReport] = []
        step_cache: Dict = {}

        shared_idx = (
            model.num_blocks - 1 if model.cfg.family == "hybrid" else None
        )
        shared_sites: List[Tuple] = []

        def visit(i, bp, ctx):
            mask_bp = model.get_block(masks, i)
            data = list(
                zip(ctx["h_mb"], ctx["target_mb"], ctx["pos_mb"], ctx["aux_mb"])
            )
            if i == shared_idx:
                shared_sites.extend(data)  # tune once on the union (sum of sites)
                return None
            tuned, rep = tune_block(model, i, bp, mask_bp, data, ecfg, step_cache)
            reports.append(rep)
            if log:
                log(
                    f"block {i:3d} [{rep.kind}] epochs={rep.epochs_run} "
                    f"E: {rep.loss_before:.3e} -> {rep.loss_after:.3e}"
                )
            return tuned

        result = C.walk_blocks(
            model,
            dense_params,
            calib,
            visit,
            microbatch=ecfg.microbatch,
            extra_batch=extra_batch,
            params_student=student,
            dual_stream=True,
        )

        if shared_idx is not None and shared_sites:
            bp = model.get_block(result, shared_idx)
            mask_bp = model.get_block(masks, shared_idx)
            tuned, rep = tune_block(
                model, shared_idx, bp, mask_bp, shared_sites, ecfg, step_cache
            )
            reports.append(rep)
            if log:
                log(
                    f"shared block [{rep.kind}] ({len(shared_sites)} site-batches) "
                    f"E: {rep.loss_before:.3e} -> {rep.loss_after:.3e}"
                )
            result = model.set_block(result, shared_idx, tuned)
    return result, reports

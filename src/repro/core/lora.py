"""LoRA baseline (paper §4.4), built from scratch.

Adapters A ∈ R^{R×r}, B ∈ R^{r×O} on the canonical 2-D view of every
prunable leaf; the effective weight during fine-tuning and at merge is

    W_eff = (M ⊙ W)  +  (α/r) · M ⊙ (A B)

(the adapter delta is masked too, so the comparison against EBFT is at
*equal* sparsity — see DESIGN.md §7). LoRA trains on the *LM loss* over a
large(ish) dataset — the paper's point is that EBFT reaches better
perplexity from 256 calibration samples in a tenth of the time; our
benchmarks reproduce the ordering with step-count as the cost proxy.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.optim.optimizers import adamw, apply_updates, clip_by_global_norm
from repro.sparsity import sparse_params as SP

Params = Any


@dataclasses.dataclass
class LoRAConfig:
    rank: int = 8
    alpha: float = 16.0
    lr: float = 1e-4
    steps: int = 200
    batch: int = 8
    weight_decay: float = 0.0
    seed: int = 0


def init_lora(params: Params, lcfg: LoRAConfig) -> Params:
    """A ~ N(0, 1/R), B = 0 (delta starts at zero) per prunable leaf."""
    rng = [jax.random.PRNGKey(lcfg.seed)]

    def g(path, w):
        if not SP.is_prunable(path, w):
            return None
        name = SP._path_names(path)[-1]
        mat, _ = SP.to_matrix(name, w)
        rng[0], k = jax.random.split(rng[0])
        if mat.ndim == 3:  # expert-batched (E, R, O)
            E, R_, O = mat.shape
            return {
                "A": (jax.random.normal(k, (E, R_, lcfg.rank)) / jnp.sqrt(R_)).astype(jnp.float32),
                "B": jnp.zeros((E, lcfg.rank, O), jnp.float32),
            }
        R_, O = mat.shape
        return {
            "A": (jax.random.normal(k, (R_, lcfg.rank)) / jnp.sqrt(R_)).astype(jnp.float32),
            "B": jnp.zeros((lcfg.rank, O), jnp.float32),
        }

    return jax.tree_util.tree_map_with_path(g, params)


def merge(params: Params, masks: Params, lora: Params, lcfg: LoRAConfig) -> Params:
    """Effective params: masked base + masked (α/r)·AB."""
    scale = lcfg.alpha / lcfg.rank

    def g(path, w, m, ab):
        if ab is None or not SP.is_prunable(path, w):
            return w * m.astype(w.dtype) if getattr(m, "ndim", 0) else w
        name = SP._path_names(path)[-1]
        mat, tag = SP.to_matrix(name, w)
        mmat, _ = SP.to_matrix(name, m)
        delta = jnp.einsum("...rk,...ko->...ro", ab["A"], ab["B"]) * scale
        eff = (mat * mmat + delta * mmat).astype(w.dtype)
        return SP.from_matrix(eff, tag)

    return jax.tree_util.tree_map_with_path(
        g, params, masks, lora, is_leaf=lambda x: x is None
    )


def finetune_lora(
    model,
    pruned_params: Params,
    masks: Params,
    data_iter: Iterator[np.ndarray],
    lcfg: Optional[LoRAConfig] = None,
    extra_batch_fn: Optional[Callable[[int], Dict[str, np.ndarray]]] = None,
    log=None,
):
    """Train adapters on the LM loss; returns merged sparse params."""
    lcfg = lcfg or LoRAConfig()
    lora = init_lora(pruned_params, lcfg)
    opt = adamw(lcfg.lr, weight_decay=lcfg.weight_decay)
    opt_state = opt.init(lora)

    def loss_fn(lora_p, batch):
        eff = merge(pruned_params, masks, lora_p, lcfg)
        loss, _ = model.loss(eff, batch)
        return loss

    @jax.jit
    def step(lora_p, opt_state, batch):
        loss, g = jax.value_and_grad(loss_fn)(lora_p, batch)
        g, _ = clip_by_global_norm(g, 1.0)
        upd, opt_state = opt.update(g, opt_state, lora_p)
        return apply_updates(lora_p, upd), opt_state, loss

    for s in range(lcfg.steps):
        tokens = next(data_iter)
        batch = {"tokens": jnp.asarray(tokens)}
        if extra_batch_fn:
            batch.update({k: jnp.asarray(v) for k, v in extra_batch_fn(s).items()})
        lora, opt_state, loss = step(lora, opt_state, batch)
        if log and s % max(1, lcfg.steps // 10) == 0:
            # obs: sync-ok (caller-requested logging, 1-in-10 cadence)
            log(f"lora step {s}: lm-loss {float(loss):.4f}")
    return merge(pruned_params, masks, lora, lcfg)

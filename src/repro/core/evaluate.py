"""Evaluation: held-out perplexity (Wikitext2 stand-in) and the synthetic
cloze ranking task (zero-shot-suite stand-in, Tab. 3)."""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

Params = Any


def perplexity(
    model,
    params: Params,
    tokens: np.ndarray,  # (N, S)
    microbatch: int = 8,
    extra_batch: Optional[Dict[str, np.ndarray]] = None,
) -> float:
    """exp(mean next-token NLL) over the evaluation segments."""

    @jax.jit
    def nll(p, batch):
        loss, m = model.loss(p, batch)
        return m["nll"] if "nll" in m else loss

    # token-weighted NLL accumulates on device; one scalar sync at the end
    tot, n = jnp.zeros((), jnp.float32), 0
    for s in range(0, tokens.shape[0], microbatch):
        batch = {"tokens": jnp.asarray(tokens[s : s + microbatch])}
        if extra_batch:
            for k, v in extra_batch.items():
                batch[k] = jnp.asarray(v[s : s + microbatch])
        b = batch["tokens"].shape[0]
        tot = tot + nll(params, batch) * b
        n += b
    return float(np.exp(float(tot) / max(n, 1)))


def cloze_accuracy(
    model,
    params: Params,
    ctx: np.ndarray,        # (N, S)
    true_next: np.ndarray,  # (N,)
    distract: np.ndarray,   # (N,)
    microbatch: int = 8,
    extra_batch: Optional[Dict[str, np.ndarray]] = None,
) -> float:
    """Fraction of samples where the model ranks the true continuation
    above the distractor at the final position."""

    @jax.jit
    def last_logits(p, batch):
        return model.forward(p, batch)[:, -1]

    # hit counts accumulate on device; one scalar sync at the end
    correct, n = jnp.zeros((), jnp.int32), 0
    for s in range(0, ctx.shape[0], microbatch):
        batch = {"tokens": jnp.asarray(ctx[s : s + microbatch])}
        if extra_batch:
            for k, v in extra_batch.items():
                batch[k] = jnp.asarray(v[s : s + microbatch])
        lg = last_logits(params, batch)
        t = jnp.asarray(true_next[s : s + microbatch])
        d = jnp.asarray(distract[s : s + microbatch])
        idx = jnp.arange(lg.shape[0])
        correct = correct + jnp.sum(lg[idx, t] > lg[idx, d])
        n += lg.shape[0]
    return int(correct) / max(n, 1)

"""Wanda (Sun et al. 2023): score_ij = |W_ij| · ‖X_i‖₂.

The comparison group is per-output (each output unit keeps its own top
(1−s) fraction of inputs), which is Wanda's key design choice. Activation
column norms come from the calibration walk (pruned-stream convention, as
in the official implementation).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.sparsity import sparse_params as SP


def leaf_scores(name: str, mat, stats):
    """mat: canonical (R, O) or (E, R, O). stats: LeafStats for this leaf."""
    norm = stats.col_norm  # (R,) or (E, R)
    if name == "conv_w":  # taps give per-channel (= output-axis) norms
        return jnp.abs(mat) * norm[None, :]
    if mat.ndim == 3:  # expert-batched
        return jnp.abs(mat) * norm[:, :, None]
    return jnp.abs(mat) * norm[:, None]


def leaf_mask(name: str, leaf, stats, sparsity: float, pattern=None):
    mat, tag = SP.to_matrix(name, leaf)
    if stats is None:  # no tap for this leaf — magnitude fallback
        scores = jnp.abs(mat)
    else:
        scores = leaf_scores(name, mat, stats)
    if pattern is not None:
        if name == "conv_w":
            return SP.from_matrix(jnp.ones_like(scores), tag)
        mask = SP.nm_mask(scores, *pattern)
    else:
        mask = SP.topk_mask_rows(scores, sparsity)  # per-output group
    return SP.from_matrix(mask, tag)

"""SparseGPT (Frantar & Alistarh 2023): OBS pruning + closed-form weight update.

Exact algorithm in our canonical (R=reduction, O=out) layout:

  H     = X Xᵀ + λ I                         (R, R)  from calibration
  U     = chol(H⁻¹)ᵀ  (upper)                 — iteration-stable inverse
  for each reduction index v (in blocks of Bs):
      score_vo = W[v,o]² / U[v,v]²
      choose pruned set within the block (unstructured: per-output top-k
      over the block; N:M: per M-group along v)
      e = (W[v,:] ⊙ pruned) / U[v,v]
      W[v:, :] -= U[v, v:]ᵀ ⊗ e              (error compensation)

This both *masks* and *updates the surviving weights* — the paper's
Tab. 1 shows SparseGPT > Wanda at high sparsity for exactly this reason,
and EBFT improves on both.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.sparsity import sparse_params as SP


def _hinv_upper(H: jnp.ndarray, damp_frac: float = 0.01) -> jnp.ndarray:
    R = H.shape[-1]
    damp = damp_frac * jnp.mean(jnp.diagonal(H, axis1=-2, axis2=-1), axis=-1)
    Hd = H + (damp[..., None, None] + 1e-8) * jnp.eye(R, dtype=H.dtype)
    Hinv = jnp.linalg.inv(Hd)
    # upper Cholesky factor of H^-1 (as in the reference implementation)
    return jnp.linalg.cholesky(
        Hinv + 1e-9 * jnp.eye(R, dtype=H.dtype), upper=True
    )


def prune_matrix(
    W: jnp.ndarray,  # (R, O) canonical view, f32
    H: jnp.ndarray,  # (R, R) Gram
    sparsity: float,
    pattern: Optional[Tuple[int, int]] = None,
    block: int = 128,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (updated weights, mask) — both (R, O)."""
    R, O = W.shape
    U = _hinv_upper(H)
    W = W.astype(jnp.float32)
    mask = jnp.ones((R, O), jnp.float32)

    Bs = min(block, R)
    if pattern is not None:
        n, m = pattern
        Bs = max(Bs - Bs % m, m)  # block must align with M-groups

    v = 0
    while v < R:
        b = min(Bs, R - v)
        Wb = jax.lax.dynamic_slice(W, (v, 0), (b, O))
        du = jnp.diagonal(U)[v : v + b]  # (b,)
        scores = jnp.square(Wb) / jnp.square(du)[:, None]
        if pattern is not None:
            mb = SP.nm_mask(scores, *pattern)
        else:
            mb = SP.topk_mask_rows(scores, sparsity)

        # eliminate the block's pruned weights row by row, compensating
        def body(carry, r):
            W_, = carry
            row = jax.lax.dynamic_slice(W_, (v + r, 0), (1, O))[0]
            pruned = (1.0 - jax.lax.dynamic_slice(mb, (r, 0), (1, O))[0])
            e = row * pruned / du[r]  # (O,)
            # compensate all later rows (within and beyond the block)
            col = jax.lax.dynamic_slice(U, (v + r, 0), (1, R))[0]  # (R,)
            upd = col[:, None] * e[None, :]  # (R, O)
            # only rows > v+r get updated; row v+r itself gets zeroed
            rows = jnp.arange(R)
            sel = (rows > v + r).astype(W_.dtype)[:, None]
            W_ = W_ - upd * sel
            W_ = W_.at[v + r].set(row * (1.0 - pruned))
            return (W_,), None

        (W,), _ = jax.lax.scan(body, (W,), jnp.arange(b))
        mask = jax.lax.dynamic_update_slice(mask, mb, (v, 0))
        v += b
    return W * mask, mask


def leaf_prune(name: str, leaf, stats, sparsity: float, pattern=None):
    """Returns (new leaf weights, mask leaf)."""
    mat, tag = SP.to_matrix(name, leaf)
    if stats is None or stats.hessian is None or name == "conv_w":
        # conv / un-tapped: Wanda-style mask, no update
        from repro.core.pruning import wanda

        mask = SP.to_matrix(name, wanda.leaf_mask(name, leaf, stats, sparsity, pattern))[0]
        return SP.from_matrix(mat * mask, tag), SP.from_matrix(mask, tag)
    if mat.ndim == 3:  # expert-batched: vmap over experts
        fn = jax.vmap(lambda w, h: prune_matrix(w, h, sparsity, pattern))
        Wn, mk = fn(mat.astype(jnp.float32), stats.hessian)
    else:
        Wn, mk = prune_matrix(mat.astype(jnp.float32), stats.hessian, sparsity, pattern)
    return SP.from_matrix(Wn.astype(leaf.dtype), tag), SP.from_matrix(mk, tag)

"""DSnoT (Zhang et al. 2023d): training-free mask reselection.

Baseline the paper compares EBFT against. Starting from any initial mask,
DSnoT iteratively swaps (grow one pruned weight, prune one kept weight)
per output unit to shrink the *expected* reconstruction error

    E_o = Σ_{pruned r} W[r,o] · μ_r ,   μ_r = E[X_r]  (calibration mean)

Growing restores the pruned weight whose lost contribution best cancels
E_o (signed criterion); pruning removes the kept weight with the smallest
Wanda score among those whose removal also pushes E_o toward zero. A swap
is committed only when it strictly reduces |E_o| — when no swap helps, the
output unit is converged (the paper's early-stop per row). Weights are
never updated — DSnoT is mask-only, which is exactly the limitation EBFT's
weight tuning fixes (paper §4.5).

Under N:M, swaps are restricted to the grow-candidate's own M-group so the
pattern is preserved.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.sparsity import sparse_params as SP

_BIG = 1e30


def reselect(
    W: jnp.ndarray,        # (R, O) canonical weights
    mask: jnp.ndarray,     # (R, O) initial mask
    mean: jnp.ndarray,     # (R,) calibration mean inputs
    col_norm: jnp.ndarray, # (R,) calibration ‖X_r‖₂ (Wanda prune criterion)
    cycles: int = 30,
    pattern: Optional[Tuple[int, int]] = None,
) -> jnp.ndarray:
    R, O = W.shape
    W = W.astype(jnp.float32)
    c = W * mean.astype(jnp.float32)[:, None]          # contribution if kept
    wanda = jnp.abs(W) * col_norm.astype(jnp.float32)[:, None]
    if pattern is not None:
        group = jnp.arange(R) // pattern[1]            # (R,)

    def body(mask, _):
        E = ((1.0 - mask) * c).sum(axis=0)             # (O,)
        sgn = jnp.sign(E)
        # --- grow: pruned weight whose restoration reduces |E| the most
        gain_g = jnp.where(mask < 0.5, c * sgn[None, :], -_BIG)
        r_g = jnp.argmax(gain_g, axis=0)               # (O,)
        g_gain = jnp.take_along_axis(gain_g, r_g[None, :], axis=0)[0]
        # --- prune: kept weight; its removal adds c to E, so require
        # c·sgn < 0 (pushes E toward zero); among those, smallest Wanda score
        push_ok = (c * sgn[None, :]) < 0
        cand = (mask > 0.5) & push_ok
        if pattern is not None:
            same_group = group[:, None] == group[r_g][None, :]
            cand = cand & same_group
        score = jnp.where(cand, wanda, _BIG)
        r_p = jnp.argmin(score, axis=0)                # (O,)
        p_cost = jnp.take_along_axis(c * sgn[None, :], r_p[None, :], axis=0)[0]
        has_p = jnp.take_along_axis(cand, r_p[None, :], axis=0)[0]

        newE_abs = jnp.abs(jnp.abs(E) - g_gain + p_cost)
        do = has_p & (g_gain > 0) & (newE_abs < jnp.abs(E))
        oi = jnp.arange(O)
        grown = mask.at[r_g, oi].set(jnp.where(do, 1.0, mask[r_g, oi]))
        swapped = grown.at[r_p, oi].set(jnp.where(do, 0.0, grown[r_p, oi]))
        return swapped, None

    mask, _ = jax.lax.scan(body, mask.astype(jnp.float32), None, length=cycles)
    return mask


def leaf_reselect(name: str, leaf, mask_leaf, stats, cycles=30, pattern=None):
    if stats is None or name == "conv_w":
        return mask_leaf  # nothing to re-select without taps
    mat, tag = SP.to_matrix(name, leaf)
    mk, _ = SP.to_matrix(name, mask_leaf)
    if mat.ndim == 3:  # expert-batched
        fn = jax.vmap(lambda w, m, mu, cn: reselect(w, m, mu, cn, cycles, pattern))
        new = fn(mat, mk, stats.mean, stats.col_norm)
    else:
        new = reselect(mat, mk, stats.mean, stats.col_norm, cycles, pattern)
    return SP.from_matrix(new, tag)

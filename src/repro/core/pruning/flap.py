"""FLAP (An et al. 2023): fluctuation-based adaptive structured pruning.

Structured units are attention heads and MLP hidden channels. Each unit's
importance is the *fluctuation* of its input feature around the
calibration mean, weighted by the squared norm of the weights that consume
it:

    head  h:  Σ_{cols j∈h}  Var-mass(X_j) · ‖W_o[j, :]‖²
    chan  j:  Var-mass(X_j) · ‖W_down[j, :]‖²

Scores are standardized per layer (FLAP's cross-layer normalization) and a
single global threshold selects which units go, "adaptively" distributing
sparsity across layers. Masks stay elementwise (broadcast from unit masks)
so EBFT / LoRA fine-tuning consume them unchanged.

Note (DESIGN.md §7): FLAP's bias compensation is skipped — our blocks are
bias-free; recovery is delegated to the fine-tuning stage, which is
precisely the EBFT-vs-LoRA comparison of paper Tab. 4/5. Applies to
standard attention+MLP blocks (the paper uses it on Llama).
"""
from __future__ import annotations

from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Params = dict


def block_unit_scores(bp: Params, stats, cfg) -> Dict[str, jnp.ndarray]:
    """Per-unit fluctuation scores for one attention+MLP block."""
    out: Dict[str, jnp.ndarray] = {}
    # attention heads: wo is (H, hd, d); taps "wo" give (T, H*hd) stats
    st_o = stats.get("wo")
    if st_o is not None:
        H, hd, d = bp["attn"]["wo"].shape
        fluct = st_o.fluctuation.reshape(H, hd)            # (H, hd)
        wnorm = jnp.sum(jnp.square(bp["attn"]["wo"].astype(jnp.float32)), axis=2)
        out["heads"] = jnp.sum(fluct * wnorm, axis=1)      # (H,)
    # MLP channels: w_down is (ff, d); taps "w_down" give (T, ff) stats
    st_d = stats.get("w_down")
    if st_d is not None:
        wnorm = jnp.sum(jnp.square(bp["mlp"]["w_down"].astype(jnp.float32)), axis=1)
        out["channels"] = st_d.fluctuation * wnorm         # (ff,)
    return out


def _standardize(x: jnp.ndarray) -> jnp.ndarray:
    return (x - x.mean()) / jnp.maximum(x.std(), 1e-9)


def global_structured_masks(
    per_block_scores: List[Dict[str, jnp.ndarray]], sparsity: float
) -> List[Dict[str, jnp.ndarray]]:
    """Standardize scores per layer, pick one global threshold, return
    per-block {heads: (H,), channels: (ff,)} 0/1 unit masks."""
    std_scores = [
        {k: _standardize(v) for k, v in s.items()} for s in per_block_scores
    ]
    allv = jnp.concatenate([v.reshape(-1) for s in std_scores for v in s.values()])
    k = max(1, int(round(allv.size * (1.0 - sparsity))))
    thresh = jnp.sort(allv)[-k]
    out = []
    for s in std_scores:
        m = {k_: (v >= thresh).astype(jnp.float32) for k_, v in s.items()}
        # never prune every head / every channel of a block
        for k_ in m:
            m[k_] = jax.lax.cond(
                m[k_].sum() < 1.0,
                lambda mm: mm.at[jnp.argmax(s[k_])].set(1.0),
                lambda mm: mm,
                m[k_],
            )
        out.append(m)
    return out


def expand_block_masks(bp: Params, unit: Dict[str, jnp.ndarray], masks_bp: Params) -> Params:
    """Broadcast unit masks into the block's elementwise mask pytree."""
    new = jax.tree.map(lambda m: m, masks_bp)  # copy
    if "heads" in unit:
        hm = unit["heads"]                                  # (H,)
        H, hd, d = bp["attn"]["wo"].shape
        Hkv = bp["attn"]["wk"].shape[1]
        new["attn"]["wq"] = jnp.broadcast_to(
            hm[None, :, None], bp["attn"]["wq"].shape
        ).astype(jnp.float32)
        new["attn"]["wo"] = jnp.broadcast_to(
            hm[:, None, None], bp["attn"]["wo"].shape
        ).astype(jnp.float32)
        if Hkv == H:  # MHA: prune kv with the head; GQA: keep shared kv
            for kname in ("wk", "wv"):
                new["attn"][kname] = jnp.broadcast_to(
                    hm[None, :, None], bp["attn"][kname].shape
                ).astype(jnp.float32)
    if "channels" in unit:
        cm = unit["channels"]                               # (ff,)
        new["mlp"]["w_up"] = jnp.broadcast_to(
            cm[None, :], bp["mlp"]["w_up"].shape
        ).astype(jnp.float32)
        if "w_gate" in bp["mlp"]:
            new["mlp"]["w_gate"] = jnp.broadcast_to(
                cm[None, :], bp["mlp"]["w_gate"].shape
            ).astype(jnp.float32)
        new["mlp"]["w_down"] = jnp.broadcast_to(
            cm[:, None], bp["mlp"]["w_down"].shape
        ).astype(jnp.float32)
    return new


def remaining_param_fraction(masks, params) -> float:
    from repro.sparsity.sparse_params import sparsity_of

    return 1.0 - sparsity_of(masks, params)

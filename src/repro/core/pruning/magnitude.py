"""Magnitude pruning (Han et al. 2015): score = |W|, whole-leaf comparison.

Needs no calibration data — masks come straight from the weights.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.sparsity import sparse_params as SP


def leaf_mask(name: str, leaf, sparsity: float, pattern=None):
    """pattern: None for unstructured, (n, m) for N:M.

    Stack-aware (to_matrix_stacked): whole-tree leaves carry leading
    (L / G,K / E) axes; scores/masks are computed per stacked slice so
    the N:M groups and the magnitude comparison group stay per-layer."""
    mat, tag = SP.to_matrix_stacked(name, leaf)
    scores = jnp.abs(mat)
    if pattern is not None:
        if name == "conv_w":  # 4-tap depthwise conv: N:M degenerate, keep dense
            return SP.from_matrix(jnp.ones_like(scores), tag)
        n, m = pattern
        mask = SP.nm_mask(scores, n, m)
    else:
        mask = SP.global_topk_mask(scores, sparsity)
    return SP.from_matrix(mask, tag)


def make_masks(params, sparsity: float, pattern=None):
    """Whole-model magnitude masks (no data, no stream walk needed)."""
    def g(name, leaf):
        return leaf_mask(name, leaf, sparsity, pattern)

    masks = SP.map_prunable(g, params)
    # non-prunable leaves must carry scalar ones, not the weights themselves
    import jax

    def fix(path, m, p):
        return m if SP.is_prunable(path, p) else jnp.ones((), jnp.float32)

    return jax.tree_util.tree_map_with_path(lambda path, m, p: fix(path, m, p), masks, params)

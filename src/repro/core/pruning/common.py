"""Shared machinery for the calibration-based pruning methods.

All methods (magnitude / Wanda / SparseGPT / DSnoT / FLAP) are *layer-wise*
inside a *block-wise* walk: the dense hidden stream is propagated block by
block over the calibration set D_c, the per-linear input activations are
tapped (sparsity/taps.py), and per-leaf statistics are accumulated:

    n        total tokens seen
    sum      Σ_t X[t]              (R,)   — DSnoT's signed expected input
    sumsq    Σ_t X[t]²             (R,)   — Wanda's ‖X_j‖₂², FLAP fluctuation
    hessian  Σ_t X[t] X[t]ᵀ        (R,R)  — SparseGPT's Gram (opt-in)

Expert-batched leaves get an extra leading E axis on every stat.

The walk processes the calibration set in microbatches, so peak memory is
one block + one microbatch of activations — the same 16 GB-GPU streaming
property the paper exploits, expressed in JAX.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import reconstruction as R
from repro.sparsity import sparse_params as SP
from repro.sparsity.taps import taps_for_block

Params = Any


def tap_key(path_names: Tuple[str, ...]) -> str:
    """Map a block-param leaf path to its taps-dict key."""
    return "/".join(path_names[-2:])


def lookup_tap(taps: Dict[str, jax.Array], names: Tuple[str, ...]):
    k2 = tap_key(names)
    if k2 in taps:
        return taps[k2]
    return taps.get(names[-1])


def iter_prunable(block_params: Params):
    """Yields (path_names, leaf) for every prunable leaf of a block."""
    out = []

    def g(path, leaf):
        if SP.is_prunable(path, leaf):
            out.append((SP._path_names(path), leaf))
        return leaf

    jax.tree_util.tree_map_with_path(g, block_params)
    return out


# ---------------------------------------------------------------------------
@dataclasses.dataclass
class LeafStats:
    n: float
    sum: jax.Array      # (R,) or (E, R)
    sumsq: jax.Array    # (R,) or (E, R)
    hessian: Optional[jax.Array] = None  # (R, R) or (E, R, R)

    @property
    def mean(self):
        return self.sum / max(self.n, 1.0)

    @property
    def col_norm(self):
        return jnp.sqrt(jnp.maximum(self.sumsq, 0.0))

    @property
    def fluctuation(self):
        """Σ (X - mean)² per column (FLAP's variance mass)."""
        return jnp.maximum(self.sumsq - self.n * jnp.square(self.mean), 0.0)


def _acc_stats(x: jax.Array, want_hessian: bool) -> LeafStats:
    """x: (T, R) or (E, C, R) activation matrix for one microbatch."""
    x32 = x.astype(jnp.float32)
    if x.ndim == 3:  # expert-batched
        n = float(x.shape[1])
        s = x32.sum(axis=1)
        ss = jnp.square(x32).sum(axis=1)
        h = jnp.einsum("ecr,ecs->ers", x32, x32) if want_hessian else None
    else:
        n = float(x.shape[0])
        s = x32.sum(axis=0)
        ss = jnp.square(x32).sum(axis=0)
        h = x32.T @ x32 if want_hessian else None
    return LeafStats(n, s, ss, h)


def _merge(a: Optional[LeafStats], b: LeafStats) -> LeafStats:
    if a is None:
        return b
    h = None
    if b.hessian is not None:
        h = (a.hessian if a.hessian is not None else 0) + b.hessian
    return LeafStats(a.n + b.n, a.sum + b.sum, a.sumsq + b.sumsq, h)


# ---------------------------------------------------------------------------
def collect_block_stats(
    model,
    bp: Params,
    block_index: int,
    h_mb: List[jax.Array],
    pos_mb: List[jax.Array],
    aux_mb: List[Dict],
    want_hessian: bool = False,
) -> Dict[str, LeafStats]:
    """Run taps over each microbatch of the stream; accumulate stats."""
    cfg = model.cfg
    tapfn = taps_for_block(cfg, block_index, model.num_blocks)
    tap_jit = jax.jit(lambda bp_, h_, p_, aux_: tapfn(bp_, cfg, h_, p_, **aux_))

    stats: Dict[str, LeafStats] = {}
    for h, pos, aux in zip(h_mb, pos_mb, aux_mb):
        taps = tap_jit(bp, h, pos, aux)
        for key, x in taps.items():
            stats[key] = _merge(stats.get(key), _acc_stats(x, want_hessian))
    return stats


def stats_for_leaf(stats: Dict[str, LeafStats], names: Tuple[str, ...]) -> Optional[LeafStats]:
    k2 = tap_key(names)
    if k2 in stats:
        return stats[k2]
    return stats.get(names[-1])


# ---------------------------------------------------------------------------
# The block-by-block walk shared by the pruning drivers and EBFT.
# ---------------------------------------------------------------------------
def walk_blocks(
    model,
    params: Params,
    calib: np.ndarray,  # (N, S) token segments
    visit_fn: Callable,  # (block_index, bp, stream_ctx) -> new bp or None
    microbatch: int = 8,
    extra_batch: Optional[Dict[str, np.ndarray]] = None,
    params_student: Optional[Params] = None,
    dual_stream: bool = False,
):
    """Block-by-block calibration walk.

    Single-stream mode (pruning: Wanda/SparseGPT/DSnoT convention): one
    stream advances through the *already-updated* blocks; each visit sees
    that stream as input and the dense block's output on the same input as
    ``target_mb``.

    Dual-stream mode (EBFT, Eq. 3/4): the teacher stream propagates through
    the dense ``params`` and the student stream through
    ``params_student``; visits see student inputs (``h_mb``) and pure
    teacher outputs (``target_mb``).

    stream_ctx fields: h_mb, pos_mb, aux_mb, target_mb, site.
    Returns the updated student/pruned params.
    """
    out_params = params_student if params_student is not None else params
    batch_all = _make_batches(model.cfg, calib, extra_batch, microbatch)

    adv = jax.jit(
        lambda bp, h, pos, aux, i: model.apply_block(None, i, bp, h, pos, **aux),
        static_argnames=("i",),
    )

    for seg in R.execution_plan(model):
        h0_jit = jax.jit(seg.h0)
        aux_jit = jax.jit(seg.aux)
        hs_mb, ht_mb, pos_mb, aux_s, aux_t = [], [], [], [], []
        for b in batch_all:
            h, pos = h0_jit(params, b)
            ht_mb.append(h)
            pos_mb.append(pos)
            aux_t.append(aux_jit(params, b))
            if dual_stream:
                h_s, _ = h0_jit(out_params, b)
                hs_mb.append(h_s)
                aux_s.append(aux_jit(out_params, b))
        if not dual_stream:
            hs_mb, aux_s = ht_mb, aux_t

        for (i, site) in seg.visits:
            dense_bp = model.get_block(params, i)
            # teacher/“dense on same input” targets
            target_mb = [
                adv(dense_bp, h, p, a, i)
                for h, p, a in zip(
                    (ht_mb if dual_stream else hs_mb), pos_mb,
                    (aux_t if dual_stream else aux_s),
                )
            ]
            bp = model.get_block(out_params, i)
            ctx = dict(
                h_mb=hs_mb, pos_mb=pos_mb, aux_mb=aux_s, target_mb=target_mb,
                site=site,
            )
            new_bp = visit_fn(i, bp, ctx)
            if new_bp is not None:
                out_params = model.set_block(out_params, i, new_bp)
                bp = new_bp
            # advance streams
            if dual_stream:
                ht_mb = target_mb
                hs_mb = [
                    adv(bp, h, p, a, i) for h, p, a in zip(hs_mb, pos_mb, aux_s)
                ]
            else:
                hs_mb = ht_mb = [
                    adv(bp, h, p, a, i) for h, p, a in zip(hs_mb, pos_mb, aux_s)
                ]
    return out_params


def _make_batches(cfg, calib, extra_batch, microbatch: int) -> List[Dict[str, jax.Array]]:
    n = calib.shape[0]
    out = []
    for s in range(0, n, microbatch):
        b = {"tokens": jnp.asarray(calib[s : s + microbatch])}
        if extra_batch:
            for k, v in extra_batch.items():
                b[k] = jnp.asarray(v[s : s + microbatch])
        out.append(b)
    return out

"""Shared machinery for the calibration-based pruning methods.

All methods (magnitude / Wanda / SparseGPT / DSnoT / FLAP) are *layer-wise*
inside a *block-wise* walk: the dense hidden stream is propagated block by
block over the calibration set D_c, the per-linear input activations are
tapped (sparsity/taps.py), and per-leaf statistics are accumulated:

    n        total tokens seen
    sum      Σ_t X[t]              (R,)   — DSnoT's signed expected input
    sumsq    Σ_t X[t]²             (R,)   — Wanda's ‖X_j‖₂², FLAP fluctuation
    hessian  Σ_t X[t] X[t]ᵀ        (R,R)  — SparseGPT's Gram (opt-in)

Expert-batched leaves get an extra leading E axis on every stat.

The walk processes the calibration set in microbatches, so peak memory is
one block + one microbatch of activations — the same 16 GB-GPU streaming
property the paper exploits, expressed in JAX.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import reconstruction as R
from repro.sparsity import sparse_params as SP
from repro.sparsity.taps import taps_for_block

Params = Any


def tap_key(path_names: Tuple[str, ...]) -> str:
    """Map a block-param leaf path to its taps-dict key."""
    return "/".join(path_names[-2:])


def lookup_tap(taps: Dict[str, jax.Array], names: Tuple[str, ...]):
    k2 = tap_key(names)
    if k2 in taps:
        return taps[k2]
    return taps.get(names[-1])


def iter_prunable(block_params: Params):
    """Yields (path_names, leaf) for every prunable leaf of a block."""
    out = []

    def g(path, leaf):
        if SP.is_prunable(path, leaf):
            out.append((SP._path_names(path), leaf))
        return leaf

    jax.tree_util.tree_map_with_path(g, block_params)
    return out


# ---------------------------------------------------------------------------
@dataclasses.dataclass
class LeafStats:
    n: float
    sum: jax.Array      # (R,) or (E, R)
    sumsq: jax.Array    # (R,) or (E, R)
    hessian: Optional[jax.Array] = None  # (R, R) or (E, R, R)

    @property
    def mean(self):
        return self.sum / max(self.n, 1.0)

    @property
    def col_norm(self):
        return jnp.sqrt(jnp.maximum(self.sumsq, 0.0))

    @property
    def fluctuation(self):
        """Σ (X - mean)² per column (FLAP's variance mass)."""
        return jnp.maximum(self.sumsq - self.n * jnp.square(self.mean), 0.0)


def _acc_stats(x: jax.Array, want_hessian: bool) -> LeafStats:
    """x: (T, R) or (E, C, R) activation matrix for one microbatch."""
    x32 = x.astype(jnp.float32)
    if x.ndim == 3:  # expert-batched
        n = float(x.shape[1])
        s = x32.sum(axis=1)
        ss = jnp.square(x32).sum(axis=1)
        h = jnp.einsum("ecr,ecs->ers", x32, x32) if want_hessian else None
    else:
        n = float(x.shape[0])
        s = x32.sum(axis=0)
        ss = jnp.square(x32).sum(axis=0)
        h = x32.T @ x32 if want_hessian else None
    return LeafStats(n, s, ss, h)


def _merge(a: Optional[LeafStats], b: LeafStats) -> LeafStats:
    if a is None:
        return b
    h = None
    if b.hessian is not None:
        h = (a.hessian if a.hessian is not None else 0) + b.hessian
    return LeafStats(a.n + b.n, a.sum + b.sum, a.sumsq + b.sumsq, h)


# ---------------------------------------------------------------------------
def collect_block_stats(
    model,
    bp: Params,
    block_index: int,
    h_mb: List[jax.Array],
    pos_mb: List[jax.Array],
    aux_mb: List[Dict],
    want_hessian: bool = False,
) -> Dict[str, LeafStats]:
    """Run taps over each microbatch of the stream; accumulate stats."""
    cfg = model.cfg
    tapfn = taps_for_block(cfg, block_index, model.num_blocks)
    tap_jit = jax.jit(lambda bp_, h_, p_, aux_: tapfn(bp_, cfg, h_, p_, **aux_))

    stats: Dict[str, LeafStats] = {}
    for h, pos, aux in zip(h_mb, pos_mb, aux_mb):
        taps = tap_jit(bp, h, pos, aux)
        for key, x in taps.items():
            stats[key] = _merge(stats.get(key), _acc_stats(x, want_hessian))
    return stats


def stats_for_leaf(stats: Dict[str, LeafStats], names: Tuple[str, ...]) -> Optional[LeafStats]:
    k2 = tap_key(names)
    if k2 in stats:
        return stats[k2]
    return stats.get(names[-1])


# ---------------------------------------------------------------------------
# The block-by-block walk shared by the pruning drivers and EBFT.
# ---------------------------------------------------------------------------
class Unstacked:
    """Lazy per-microbatch view over a stacked pytree.

    The stacked walk keeps each stream as ONE device array with a leading
    microbatch axis; list-consuming visitors (the pruning drivers,
    mask-tuning) still read ``ctx["h_mb"][j]`` — each access slices on
    demand, so visitors that only use the stacked form (fused EBFT) incur
    zero per-microbatch dispatches.
    """

    __slots__ = ("tree", "n")

    def __init__(self, tree, n: int):
        self.tree = tree
        self.n = n

    def __len__(self) -> int:
        return self.n

    def __getitem__(self, j):
        if not -self.n <= j < self.n:
            raise IndexError(j)
        return jax.tree.map(lambda a: a[j], self.tree)

    def __iter__(self):
        return (self[j] for j in range(self.n))


def _uniform_microbatches(batch_all: List[Dict[str, jax.Array]]) -> bool:
    """True when every microbatch has identical structure + leaf shapes
    (the stacked/fused walk needs a uniform leading axis)."""
    if not batch_all:
        return False
    leaves0, treedef0 = jax.tree.flatten(batch_all[0])
    sig0 = [(x.shape, x.dtype) for x in leaves0]
    for b in batch_all[1:]:
        leaves, treedef = jax.tree.flatten(b)
        if treedef != treedef0 or [(x.shape, x.dtype) for x in leaves] != sig0:
            return False
    return True


class TeacherPrefetcher:
    """Dispatch-ahead teacher stream for the dual-stream walk (DESIGN.md §3).

    The teacher stream depends only on the frozen dense ``params``, never
    on the student's updates, so block ``l+1..l+depth``'s teacher
    activations can be *enqueued* while block ``l``'s student is still
    fine-tuning — the teacher forward overlaps student backprop on the
    device stream. ``get(k)`` fences (``block_until_ready``) at the
    consume point, which both attributes the wait to the consumer and
    back-pressures the queue: at most ``depth + 1`` blocks of teacher
    activations are in flight, keeping the walk's streaming-memory
    property intact.

    ``depth=0`` degenerates to the strictly serial legacy order (compute
    block ``l``'s targets immediately before visiting block ``l``).
    """

    def __init__(self, model, params, visits, adv_scan, ht_st, pos_st,
                 aux_t_st, depth: int, ledger: Optional[Any] = None):
        self.model = model
        self.params = params
        self.visits = visits
        self.adv_scan = adv_scan
        self.pos_st = pos_st
        self.aux_t_st = aux_t_st
        self.depth = max(int(depth), 0)
        self.ledger = ledger
        self._ht = ht_st                    # teacher stream BEFORE visit _next
        self._targets: Dict[int, Any] = {}  # visit index -> stacked targets
        self._next = 0

    def _dispatch_until(self, k: int) -> None:
        last = min(k, len(self.visits) - 1)
        while self._next <= last:
            i, _site = self.visits[self._next]
            dense_bp = self.model.get_block(self.params, i)
            t = self.adv_scan(dense_bp, self._ht, self.pos_st, self.aux_t_st, i)
            if self.ledger is not None:
                self.ledger.dispatch()
            self._targets[self._next] = t
            self._ht = t                    # Eq. 3: teacher feeds teacher
            self._next += 1

    def in_flight(self) -> int:
        return len(self._targets)

    def get(self, k: int):
        """Teacher targets for visit ``k``, fenced at the consume point."""
        self._dispatch_until(k + self.depth)
        t = self._targets.pop(k)
        jax.block_until_ready(t)
        if self.ledger is not None:
            self.ledger.host_sync()
        return t


def walk_blocks(
    model,
    params: Params,
    calib: np.ndarray,  # (N, S) token segments
    visit_fn: Callable,  # (block_index, bp, stream_ctx) -> new bp or None
    microbatch: int = 8,
    extra_batch: Optional[Dict[str, np.ndarray]] = None,
    params_student: Optional[Params] = None,
    dual_stream: bool = False,
    prefetch_depth: int = 0,
    mesh_plan: Optional[Any] = None,
):
    """Block-by-block calibration walk.

    Single-stream mode (pruning: Wanda/SparseGPT/DSnoT convention): one
    stream advances through the *already-updated* blocks; each visit sees
    that stream as input and the dense block's output on the same input as
    ``target_mb``.

    Dual-stream mode (EBFT, Eq. 3/4): the teacher stream propagates through
    the dense ``params`` and the student stream through
    ``params_student``; visits see student inputs (``h_mb``) and pure
    teacher outputs (``target_mb``). When microbatch shapes are uniform
    the streams are kept *stacked* (one device array with a leading
    microbatch axis): each stream advance is ONE scanned dispatch per
    block, the teacher stream is produced ``prefetch_depth`` blocks ahead
    of the visitor (:class:`TeacherPrefetcher`), and visitors additionally
    receive ``h_st/target_st/pos_st/aux_st`` stacked arrays so a fused
    tuner never re-stacks. Ragged shapes fall back to the per-microbatch
    list walk.

    stream_ctx fields: h_mb, pos_mb, aux_mb, target_mb, site; stacked
    mode adds h_st, target_st, pos_st, aux_st (and the ``*_mb`` views
    become lazy slices).

    ``mesh_plan`` (:class:`repro.distributed.meshplan.MeshPlan`) shards the
    stacked streams over the mesh's batch axes — teacher and student
    activations come out data-sharded, not replicated, so a fused visitor
    runs SPMD over the calibration microbatches. Inactive/None plans and
    the ragged list walk are byte-identical to the unsharded behavior.
    Returns the updated student/pruned params.
    """
    out_params = params_student if params_student is not None else params
    batch_all = _make_batches(model.cfg, calib, extra_batch, microbatch)

    if dual_stream and _uniform_microbatches(batch_all):
        return _walk_blocks_stacked(
            model, params, out_params, batch_all, visit_fn, prefetch_depth,
            mesh_plan=mesh_plan,
        )
    return _walk_blocks_lists(
        model, params, out_params, batch_all, visit_fn, dual_stream
    )


def _walk_blocks_lists(model, params, out_params, batch_all, visit_fn,
                       dual_stream: bool):
    """Per-microbatch list walk (pruning drivers; ragged-shape fallback)."""
    adv = jax.jit(
        lambda bp, h, pos, aux, i: model.apply_block(None, i, bp, h, pos, **aux),
        static_argnames=("i",),
    )

    for seg in R.execution_plan(model):
        h0_jit = jax.jit(seg.h0)
        aux_jit = jax.jit(seg.aux)
        hs_mb, ht_mb, pos_mb, aux_s, aux_t = [], [], [], [], []
        for b in batch_all:
            h, pos = h0_jit(params, b)
            ht_mb.append(h)
            pos_mb.append(pos)
            aux_t.append(aux_jit(params, b))
            if dual_stream:
                h_s, _ = h0_jit(out_params, b)
                hs_mb.append(h_s)
                aux_s.append(aux_jit(out_params, b))
        if not dual_stream:
            hs_mb, aux_s = ht_mb, aux_t

        for (i, site) in seg.visits:
            dense_bp = model.get_block(params, i)
            # teacher/“dense on same input” targets
            target_mb = [
                adv(dense_bp, h, p, a, i)
                for h, p, a in zip(
                    (ht_mb if dual_stream else hs_mb), pos_mb,
                    (aux_t if dual_stream else aux_s),
                )
            ]
            bp = model.get_block(out_params, i)
            ctx = dict(
                h_mb=hs_mb, pos_mb=pos_mb, aux_mb=aux_s, target_mb=target_mb,
                site=site,
            )
            new_bp = visit_fn(i, bp, ctx)
            if new_bp is not None:
                out_params = model.set_block(out_params, i, new_bp)
                bp = new_bp
            # advance streams
            if dual_stream:
                ht_mb = target_mb
                hs_mb = [
                    adv(bp, h, p, a, i) for h, p, a in zip(hs_mb, pos_mb, aux_s)
                ]
            else:
                hs_mb = ht_mb = [
                    adv(bp, h, p, a, i) for h, p, a in zip(hs_mb, pos_mb, aux_s)
                ]
    return out_params


def _walk_blocks_stacked(model, params, out_params, batch_all, visit_fn,
                         prefetch_depth: int, mesh_plan=None):
    """Stacked dual-stream walk: one scanned dispatch per stream advance,
    teacher stream pipelined ``prefetch_depth`` blocks ahead. With an
    active ``mesh_plan`` the stacked streams are data-sharded at segment
    setup, so every teacher/student advance (and the prefetcher's
    in-flight targets) stays sharded — one SPMD dispatch, never a
    replicated copy per device."""
    from repro.obs import metrics as OM
    from repro.obs import trace as OT
    from repro.obs.profile import DispatchLedger, FirstCallTimer, compile_clock

    sharded = mesh_plan is not None and mesh_plan.active
    ledger = DispatchLedger(
        "ebft/walk", devices=mesh_plan.device_count if sharded else 1
    )
    clock = compile_clock()
    clock.take()  # drop compile time booked before this walk started
    n_mb = len(batch_all)

    def adv_scan_fn(bp, h_st, pos_st, aux_st, i):
        def one(args):
            h, pos, aux = args
            return model.apply_block(None, i, bp, h, pos, **aux)

        return jax.lax.map(one, (h_st, pos_st, aux_st))

    # adv_scan recompiles per static block index i; FirstCallTimer books
    # that first-call cost on the compile clock so the phase histograms
    # below can report steady-state separately (no fence is added — the
    # prefetcher's dispatch-ahead overlap is preserved)
    adv_scan = FirstCallTimer(jax.jit(adv_scan_fn, static_argnames=("i",)))
    batch_st = jax.tree.map(lambda *xs: jnp.stack(xs), *batch_all)
    if sharded:
        batch_st = mesh_plan.put_stacked(batch_st)

    for seg in R.execution_plan(model):
        # stream setup: one scanned dispatch per (stream, segment)
        h0_jit = jax.jit(lambda p, bst, h0=seg.h0: jax.lax.map(
            lambda b: h0(p, b), bst))
        aux_jit = jax.jit(lambda p, bst, aux=seg.aux: jax.lax.map(
            lambda b: aux(p, b), bst))
        ht_st, pos_st = h0_jit(params, batch_st)
        aux_t_st = aux_jit(params, batch_st)
        hs_st, _ = h0_jit(out_params, batch_st)
        aux_s_st = aux_jit(out_params, batch_st)
        if sharded:
            # pin the stream layout: activations batch-sharded over the
            # data axes (GSPMD usually propagates this from batch_st, but
            # the walk's memory property depends on it, so make it law)
            ht_st, pos_st, aux_t_st, hs_st, aux_s_st = mesh_plan.put_stacked(
                (ht_st, pos_st, aux_t_st, hs_st, aux_s_st)
            )
        ledger.dispatch(4)

        pf = TeacherPrefetcher(
            model, params, seg.visits, adv_scan, ht_st, pos_st, aux_t_st,
            prefetch_depth, ledger=ledger,
        )

        clock.take()  # segment setup compiles (h0/aux) are not a phase
        for k, (i, site) in enumerate(seg.visits):
            with OT.span("walk/teacher", block=i) as sp_t:
                target_st = pf.get(k)
            c_teacher = clock.take()
            bp = model.get_block(out_params, i)
            ctx = dict(
                h_st=hs_st, target_st=target_st, pos_st=pos_st,
                aux_st=aux_s_st, site=site,
                h_mb=Unstacked(hs_st, n_mb),
                target_mb=Unstacked(target_st, n_mb),
                pos_mb=Unstacked(pos_st, n_mb),
                aux_mb=Unstacked(aux_s_st, n_mb),
            )
            with OT.span("walk/tune", block=i) as sp_v:
                new_bp = visit_fn(i, bp, ctx)
            c_tune = clock.take()
            if new_bp is not None:
                out_params = model.set_block(out_params, i, new_bp)
                bp = new_bp
            with OT.span("walk/student", block=i) as sp_s:
                hs_st = adv_scan(bp, hs_st, pos_st, aux_s_st, i)
                ledger.dispatch()
                sp_s.fence(hs_st)
            c_student = clock.take()
            if OT.enabled():
                # steady-state vs first-call split (docs/PERF.md): the
                # compile clock holds the trace+compile wall time booked
                # inside each span; subtracting it keeps walk-phase
                # percentiles meaningful (block-0 teacher is ~all compile)
                OM.histogram("ebft/walk/teacher_s").observe(
                    max(sp_t.duration - c_teacher, 0.0))
                OM.histogram("ebft/walk/tune_s").observe(
                    max(sp_v.duration - c_tune, 0.0))
                OM.histogram("ebft/walk/student_s").observe(
                    max(sp_s.duration - c_student, 0.0))
                OM.histogram("ebft/walk/teacher_compile_s").observe(c_teacher)
                OM.histogram("ebft/walk/tune_compile_s").observe(c_tune)
                OM.histogram("ebft/walk/student_compile_s").observe(c_student)
                OM.gauge("ebft/walk/prefetch_inflight").set(pf.in_flight())
    return out_params


def _make_batches(cfg, calib, extra_batch, microbatch: int) -> List[Dict[str, jax.Array]]:
    n = calib.shape[0]
    out = []
    for s in range(0, n, microbatch):
        b = {"tokens": jnp.asarray(calib[s : s + microbatch])}
        if extra_batch:
            for k, v in extra_batch.items():
                b[k] = jnp.asarray(v[s : s + microbatch])
        out.append(b)
    return out

"""Mask tuning (paper §4.5 ablation): move masks, freeze weights.

Same block-wise walk and Eq. 4 objective as EBFT, but the optimization
variable is a continuous score tensor per prunable leaf; the forward pass
hard-thresholds scores into a mask at the target sparsity (per-output
top-k, or per-group for N:M) and a straight-through estimator passes the
gradient to the scores. Weights never change — exactly the strategy DSnoT
/ lottery-jackpots use, which the paper shows loses to weight tuning
(Tab. 6), a result our benchmarks reproduce.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import reconstruction as R
from repro.core.ebft import EBFTConfig
from repro.core.pruning import common as C
from repro.optim.optimizers import adam, apply_updates
from repro.optim.schedules import plateau_early_stop
from repro.sparsity import sparse_params as SP

Params = Any


@jax.custom_vjp
def _ste(mask: jax.Array, scores: jax.Array) -> jax.Array:
    return mask


def _ste_fwd(mask, scores):
    return mask, None


def _ste_bwd(_, g):
    return None, g  # straight-through: d mask / d scores = 1


_ste.defvjp(_ste_fwd, _ste_bwd)


def _hard_mask(name: str, scores_mat: jax.Array, sparsity: float, pattern):
    if pattern is not None:
        if name == "conv_w":
            return jnp.ones_like(scores_mat)
        return SP.nm_mask(scores_mat, *pattern)
    return SP.topk_mask_rows(scores_mat, sparsity)


def _masked_block(bp: Params, scores: Params, sparsity: float, pattern) -> Params:
    """W_eff = W ⊙ STE(hard_mask(scores)) on prunable leaves."""

    def g(path, w, s):
        if not SP.is_prunable(path, w):
            return w
        name = SP._path_names(path)[-1]
        sm, tag = SP.to_matrix(name, s)
        # the hard threshold itself is non-differentiable — gradients reach
        # the scores only through the STE, never through the sort
        hard = _hard_mask(name, jax.lax.stop_gradient(sm), sparsity, pattern)
        m = SP.from_matrix(_ste(hard, sm), tag)
        return w * m.astype(w.dtype)

    return jax.tree_util.tree_map_with_path(g, bp, scores)


def _final_masks(bp: Params, scores: Params, sparsity: float, pattern) -> Params:
    def g(path, w, s):
        if not SP.is_prunable(path, w):
            return jnp.ones(w.shape, jnp.float32)
        name = SP._path_names(path)[-1]
        sm, tag = SP.to_matrix(name, s)
        return SP.from_matrix(_hard_mask(name, sm, sparsity, pattern), tag)

    return jax.tree_util.tree_map_with_path(g, bp, scores)


# ---------------------------------------------------------------------------
def finetune_masks(
    model,
    dense_params: Params,
    init_masks: Params,
    sparsity: float,
    calib: np.ndarray,
    ecfg: Optional[EBFTConfig] = None,
    pattern: Optional[Tuple[int, int]] = None,
    extra_batch=None,
    log=None,
    bonus: float = 0.1,
) -> Tuple[Params, Params]:
    """Returns (mask-tuned sparse params, tuned masks). Weights = dense
    weights under the tuned masks (mask tuning never updates values).

    ``bonus`` is added to the initially-kept slots' scores so the starting
    hard mask ≈ the init mask; it is deliberately small relative to the
    reachable score movement (lr × steps) — a large bonus freezes the mask
    (no flips → the frozen-weight loss cannot move at all).
    """
    ecfg = ecfg or EBFTConfig(lr=2e-2)  # scores need a larger step than weights
    masks = init_masks
    student = SP.apply_masks(dense_params, masks)
    step_cache: Dict = {}

    def make_step(kind_rep_i):
        opt = adam(ecfg.lr)

        def loss_fn(scores, bp, h, target, pos, aux):
            bw = _masked_block(bp, scores, sparsity, pattern)
            out = model.apply_block(None, kind_rep_i, bw, h, pos, **aux)
            return jnp.mean(jnp.square((out - target).astype(jnp.float32)))

        vg = jax.value_and_grad(loss_fn)

        @jax.jit
        def step(scores, opt_state, bp, h, target, pos, aux):
            loss, g = vg(scores, bp, h, target, pos, aux)
            upd, opt_state = opt.update(g, opt_state, scores)
            return apply_updates(scores, upd), opt_state, loss

        return opt, step

    def visit(i, bp, ctx):
        nonlocal masks
        kind = R.block_kind(model, i)
        if kind not in step_cache:
            step_cache[kind] = make_step(i)
        opt, step = step_cache[kind]

        dense_bp = model.get_block(dense_params, i)
        mask_bp = model.get_block(masks, i)
        # scores init: per-column-normalized |W| + small bonus on kept slots
        def s0(path, w, m):
            if not SP.is_prunable(path, w):
                return jnp.zeros(w.shape, jnp.float32)
            a = jnp.abs(w.astype(jnp.float32))
            a = a / jnp.maximum(a.max(), 1e-9)
            return a + bonus * m.astype(jnp.float32)

        scores = jax.tree_util.tree_map_with_path(s0, dense_bp, mask_bp)
        opt_state = opt.init(scores)
        data = list(zip(ctx["h_mb"], ctx["target_mb"], ctx["pos_mb"], ctx["aux_mb"]))
        history: List[float] = []
        for _ in range(ecfg.epochs):
            losses = []
            for h, t, p, a in data:
                scores, opt_state, loss = step(scores, opt_state, dense_bp, h, t, p, a)
                losses.append(loss)
            # epoch mean reduced on device; one scalar transfer per epoch
            # obs: sync-ok (host-side plateau check needs the epoch mean)
            history.append(float(jnp.mean(jnp.stack(losses))))
            if plateau_early_stop(history, ecfg.patience, ecfg.rel_tol):
                break
        mask_bp = _final_masks(dense_bp, scores, sparsity, pattern)
        masks = model.set_block(masks, i, mask_bp)
        if log:
            log(f"mask-tune block {i}: E {history[0]:.3e} -> {history[-1]:.3e}")
        return SP.apply_masks(dense_bp, mask_bp)

    result = C.walk_blocks(
        model, dense_params, calib, visit, microbatch=ecfg.microbatch,
        extra_batch=extra_batch, params_student=student, dual_stream=True,
    )
    return result, masks

"""Fault-tolerant checkpointing: atomic, async, elastic-reshardable.

Layout (one directory per step):

    <dir>/step_000123.tmp/...   (written)
    <dir>/step_000123/          (atomic rename on completion)
        manifest.json           {step, leaf index, shapes/dtypes, mesh shape}
        arrays.npz              full (unsharded) leaf values

Design decisions for 1000+ node operation:
* **Atomicity** — a checkpoint is visible iff its final rename happened;
  a crash mid-write leaves only a ``.tmp`` dir that ``latest_step`` ignores
  and ``save`` garbage-collects.
* **Async** — ``save(async_write=True)`` snapshots to host memory
  (device_get) synchronously (cheap vs a training step) and writes in a
  background thread so the train loop never blocks on the filesystem.
* **Elastic restore** — arrays are HOST-GATHERED on save (a sharded
  jax.Array is assembled to one full ndarray per leaf, and the manifest
  records each leaf's source PartitionSpec) and placed on restore with
  the target sharding: an explicit ``shardings`` pytree if the caller
  passes one, else the ``NamedSharding`` carried by the corresponding
  template leaf — so ``restore(dir, {"params": params})`` on a mesh
  round-trips sharded trees without extra plumbing, and a job restarted
  on a different mesh (pod lost, data-axis shrunk) reshard-on-loads.
  (A real deployment would write per-host shards + reshard in a restore
  service; the manifest already records the source layout to support
  that.)
* **Self-describing** — restore rebuilds the pytree purely from the
  manifest, so the reader needs no template (it can also *check* against
  one, catching config drift between writer and reader).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import ml_dtypes
import numpy as np

_PENDING: list = []  # background writer threads (joinable via wait_all)

# numpy's npz cannot store ml_dtypes (bf16/f8...) natively — it silently
# degrades them to void. Store them as a same-width integer view and
# restore through the manifest's dtype string.
_VIEW_AS = {
    "bfloat16": np.uint16,
    "float8_e4m3fn": np.uint8,
    "float8_e5m2": np.uint8,
}


def _to_storable(a: np.ndarray) -> np.ndarray:
    view = _VIEW_AS.get(str(a.dtype))
    return a.view(view) if view is not None else a


def _from_storable(a: np.ndarray, dtype_str: str) -> np.ndarray:
    if dtype_str in _VIEW_AS:
        return a.view(getattr(ml_dtypes, dtype_str))
    return a


def _flatten_with_names(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    paths = jax.tree_util.tree_flatten_with_path(tree)[0]
    names = ["/".join(str(getattr(k, "key", k)) for k in p) for p, _ in paths]
    return names, leaves, treedef


def _host_gather(x) -> np.ndarray:
    """Assemble one leaf to a FULL host ndarray, whatever its sharding.

    ``device_get`` on a (single-process) sharded jax.Array gathers every
    shard; the npz writer below then stores the unsharded value, which is
    what makes restore-onto-a-different-mesh possible at all.
    """
    return np.asarray(jax.device_get(x))


def _source_spec(x) -> Optional[str]:
    """The leaf's PartitionSpec as a string, for the manifest (None for
    host arrays / single-device placements)."""
    sharding = getattr(x, "sharding", None)
    spec = getattr(sharding, "spec", None)
    return str(spec) if spec is not None else None


def save(
    directory: str,
    tree: Any,
    step: int,
    mesh_shape: Optional[tuple] = None,
    async_write: bool = False,
) -> str:
    names, leaves, treedef = _flatten_with_names(tree)
    source_specs = [_source_spec(x) for x in leaves]
    host_leaves = [_host_gather(x) for x in leaves]

    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"

    def write():
        os.makedirs(tmp, exist_ok=True)
        np.savez(os.path.join(tmp, "arrays.npz"), **{
            f"leaf_{i}": _to_storable(a) for i, a in enumerate(host_leaves)
        })
        manifest = {
            "step": step,
            "mesh_shape": list(mesh_shape) if mesh_shape else None,
            "treedef": jax.tree_util.tree_structure(tree).__repr__(),
            "names": names,
            "shapes": [list(a.shape) for a in host_leaves],
            "dtypes": [str(a.dtype) for a in host_leaves],
            "source_specs": source_specs,
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)  # atomic visibility

    # clean any stale tmp from a previous crash
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    if async_write:
        t = threading.Thread(target=write, daemon=True)
        t.start()
        _PENDING.append(t)
    else:
        write()
    return final


def wait_all() -> None:
    while _PENDING:
        _PENDING.pop().join()


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [
        int(d.split("_")[1])
        for d in os.listdir(directory)
        if d.startswith("step_") and not d.endswith(".tmp")
        and os.path.exists(os.path.join(directory, d, "manifest.json"))
    ]
    return max(steps) if steps else None


def restore(
    directory: str,
    template: Any,
    step: Optional[int] = None,
    shardings: Any = None,
) -> Any:
    """Restore into ``template``'s structure. ``shardings`` (optional pytree
    of NamedSharding matching template) enables elastic resharding: each
    full array is device_put with the *current* mesh's sharding. When no
    ``shardings`` is passed, any template leaf that is itself a mesh-placed
    jax.Array (carries a NamedSharding) is restored with that placement —
    sharded trees round-trip with no extra arguments."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {directory}")
    d = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(d, "arrays.npz"))
    leaves = [
        _from_storable(data[f"leaf_{i}"], manifest["dtypes"][i])
        for i in range(len(manifest["names"]))
    ]

    t_leaves, treedef = jax.tree_util.tree_flatten(template)
    assert len(t_leaves) == len(leaves), (
        f"checkpoint has {len(leaves)} leaves, template {len(t_leaves)} — "
        "config drift between writer and reader"
    )
    out = []
    if shardings is not None:
        s_leaves = jax.tree_util.tree_flatten(shardings)[0]
    else:
        # derive the target placement from the template itself: only
        # NamedSharding counts (a plain single-device array must stay
        # uncommitted, exactly as before)
        from jax.sharding import NamedSharding

        s_leaves = [
            s if isinstance(s, NamedSharding) else None
            for s in (getattr(t, "sharding", None) for t in t_leaves)
        ]
    for i, (a, t) in enumerate(zip(leaves, t_leaves)):
        arr = jnp.asarray(a, dtype=t.dtype)
        if s_leaves[i] is not None:
            arr = jax.device_put(arr, s_leaves[i])
        out.append(arr)
    return jax.tree_util.tree_unflatten(treedef, out)

"""Gradient compression with error feedback (distributed-optimization trick).

Top-k sparsification per leaf with an error-feedback accumulator (Stich et
al. 2018): the un-transmitted residual is added back into the next step's
gradient, preserving convergence. Used by the train loop when
``grad_compress_ratio < 1.0`` — on a real multi-pod run this shrinks the
cross-pod all-reduce payload by ~ratio (values + indices).

The compressed representation stays dense-shaped inside jit (scatter of the
kept values); the *collective* savings come from all-reducing the (values,
indices) pair instead of the dense tensor — expressed here as a custom
reduce over the top-k slots so GSPMD sees the small payload.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def init_error_state(params) -> Any:
    return jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)


def _topk_mask(x: jax.Array, k: int) -> jax.Array:
    flat = jnp.abs(x.reshape(-1))
    thresh = jax.lax.top_k(flat, k)[0][-1]
    return (jnp.abs(x) >= thresh).astype(x.dtype)


def compress_leaf(g: jax.Array, err: jax.Array, ratio: float) -> Tuple[jax.Array, jax.Array]:
    """Returns (sparse gradient to all-reduce, new error residual)."""
    if g.size < 1024 or ratio >= 1.0:  # tiny leaves: not worth compressing
        return g, err
    g32 = g.astype(jnp.float32) + err
    k = max(1, int(g.size * ratio))
    mask = _topk_mask(g32, k)
    sent = g32 * mask
    return sent.astype(g.dtype), g32 - sent


def compress(grads, err_state, ratio: float):
    """Tree-wide top-k+error-feedback. Returns (grads_to_reduce, new_err)."""
    pairs = jax.tree.map(
        lambda g, e: compress_leaf(g, e, ratio), grads, err_state
    )
    sent = jax.tree.map(lambda pr: pr[0], pairs, is_leaf=lambda x: isinstance(x, tuple))
    err = jax.tree.map(lambda pr: pr[1], pairs, is_leaf=lambda x: isinstance(x, tuple))
    return sent, err


def compressed_bytes(params, ratio: float) -> int:
    """Collective payload estimate: values (4B) + indices (4B) per kept slot."""
    total = 0
    for p in jax.tree.leaves(params):
        if p.size < 1024 or ratio >= 1.0:
            total += p.size * 4
        else:
            total += int(p.size * ratio) * 8
    return total

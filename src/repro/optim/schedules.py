"""Learning-rate schedules (plain callables: step -> lr)."""
from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    return lambda step: jnp.full((), lr, jnp.float32)


def warmup_cosine(peak: float, warmup: int, total: int, floor: float = 0.0):
    """Linear warmup to ``peak`` over ``warmup`` steps then cosine to floor."""

    def f(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak * step / jnp.maximum(warmup, 1)
        t = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
        cos = floor + 0.5 * (peak - floor) * (1 + jnp.cos(jnp.pi * t))
        return jnp.where(step < warmup, warm, cos)

    return f


def linear_decay(peak: float, warmup: int, total: int, floor: float = 0.0):
    def f(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak * step / jnp.maximum(warmup, 1)
        t = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
        lin = peak + (floor - peak) * t
        return jnp.where(step < warmup, warm, lin)

    return f


def plateau_early_stop(history, patience: int = 3, rel_tol: float = 1e-3) -> bool:
    """Host-side convergence check used by the EBFT per-block loop (the
    paper's "loss unchanged or changes within a small range" criterion).

    ``history`` is a list of float losses; returns True when the best loss
    has not improved by ``rel_tol`` (relative) for ``patience`` epochs.
    Degenerate inputs (empty history, ``patience`` longer than the history,
    non-positive ``patience``) never stop.
    """
    if patience <= 0 or len(history) < patience + 1:
        return False
    best_before = min(history[:-patience])
    recent_best = min(history[-patience:])
    return recent_best > best_before * (1.0 - rel_tol)


def plateau_early_stop_device(
    hist: jnp.ndarray, n, patience: int, rel_tol: float
) -> jnp.ndarray:
    """The same predicate as a jittable device-side expression.

    ``hist`` is a fixed-size f32 buffer whose first ``n`` entries are
    valid (the rest may hold anything); ``n`` may be a traced scalar.
    Used by the fused EBFT epoch scan (core/ebft.py) so early stopping
    needs no host round-trip. Semantics match :func:`plateau_early_stop`
    on ``hist[:n]`` exactly, including the degenerate cases.
    """
    if patience <= 0:
        return jnp.asarray(False)
    n = jnp.asarray(n, jnp.int32)
    idx = jnp.arange(hist.shape[0], dtype=jnp.int32)
    inf = jnp.asarray(jnp.inf, hist.dtype)
    best_before = jnp.min(jnp.where(idx < n - patience, hist, inf))
    recent = (idx >= n - patience) & (idx < n)
    recent_best = jnp.min(jnp.where(recent, hist, inf))
    fire = recent_best > best_before * (1.0 - rel_tol)
    return jnp.where(n >= patience + 1, fire, False)

"""Optimizers from scratch (no optax): SGD-momentum, Adam, AdamW.

Interface mirrors the (init, update) pair convention:

    opt = adam(lr=2e-4)
    state = opt.init(params)
    updates, state = opt.update(grads, state, params)
    params = apply_updates(params, updates)

``lr`` may be a float or a schedule ``f(step) -> float`` from
``repro.optim.schedules``. All states are pytrees (checkpointable,
shardable — each moment leaf inherits its param's sharding; ZeRO-1
partitioning is applied in distributed/sharding.py).

EBFT note: the paper fine-tunes one block at a time with Adam-style steps
at lr 2e-4; masked leaves get their gradient multiplied by the mask inside
the EBFT step (core/ebft.py), so the optimizer itself stays generic.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Union

import jax
import jax.numpy as jnp

Schedule = Union[float, Callable[[jax.Array], jax.Array]]


def _lr_at(lr: Schedule, step: jax.Array) -> jax.Array:
    if callable(lr):
        return jnp.asarray(lr(step), jnp.float32)
    return jnp.asarray(lr, jnp.float32)


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    update: Callable[..., Any]  # (grads, state, params) -> (updates, state)


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: (p + u.astype(p.dtype)), params, updates)


# ---------------------------------------------------------------------------
def sgd(lr: Schedule, momentum: float = 0.0, nesterov: bool = False) -> Optimizer:
    def init(params):
        mu = (
            jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
            if momentum
            else None
        )
        return {"step": jnp.zeros((), jnp.int32), "mu": mu}

    def update(grads, state, params=None):
        step = state["step"] + 1
        lr_t = _lr_at(lr, step)
        if momentum:
            mu = jax.tree.map(
                lambda m, g: momentum * m + g.astype(jnp.float32), state["mu"], grads
            )
            if nesterov:
                upd = jax.tree.map(
                    lambda m, g: -(lr_t * (momentum * m + g.astype(jnp.float32))),
                    mu,
                    grads,
                )
            else:
                upd = jax.tree.map(lambda m: -lr_t * m, mu)
            return upd, {"step": step, "mu": mu}
        upd = jax.tree.map(lambda g: -lr_t * g.astype(jnp.float32), grads)
        return upd, {"step": step, "mu": None}

    return Optimizer(init, update)


# ---------------------------------------------------------------------------
def adam(
    lr: Schedule,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
) -> Optimizer:
    """Adam (weight_decay>0 makes it AdamW: decoupled decay)."""

    def init(params):
        zeros = lambda p: jnp.zeros_like(p, jnp.float32)
        return {
            "step": jnp.zeros((), jnp.int32),
            "m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
        }

    def update(grads, state, params=None):
        step = state["step"] + 1
        lr_t = _lr_at(lr, step)
        c1 = 1.0 - b1 ** step.astype(jnp.float32)
        c2 = 1.0 - b2 ** step.astype(jnp.float32)

        m = jax.tree.map(
            lambda m_, g: b1 * m_ + (1 - b1) * g.astype(jnp.float32), state["m"], grads
        )
        v = jax.tree.map(
            lambda v_, g: b2 * v_ + (1 - b2) * jnp.square(g.astype(jnp.float32)),
            state["v"],
            grads,
        )

        def u(m_, v_, p=None):
            upd = -(lr_t * (m_ / c1) / (jnp.sqrt(v_ / c2) + eps))
            if weight_decay and p is not None:
                upd = upd - lr_t * weight_decay * p.astype(jnp.float32)
            return upd

        if weight_decay and params is not None:
            updates = jax.tree.map(u, m, v, params)
        else:
            updates = jax.tree.map(u, m, v)
        return updates, {"step": step, "m": m, "v": v}

    return Optimizer(init, update)


def adamw(lr: Schedule, weight_decay: float = 0.1, **kw) -> Optimizer:
    return adam(lr, weight_decay=weight_decay, **kw)


# ---------------------------------------------------------------------------
def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-12))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), gn

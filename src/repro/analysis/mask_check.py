"""Pass 2 — mask invariants.

EBFT freezes masks and trains only surviving weights; correctness requires
that pruned slots get **exactly zero gradient** (PAPER.md Eq. 4). That
holds iff the forward graph multiplies every prunable weight by its mask
*before* any contraction: d(loss)/dW then carries the mask factor by the
chain rule. This pass proves the property statically on the traced jaxpr
of ``reconstruction.block_loss``:

  * every jaxpr input corresponding to a prunable weight leaf is tainted
    ``W`` (unmasked weight), every mask leaf ``M``;
  * taint flows through all ops; a ``mul`` whose operands carry ``W`` and
    ``M`` produces ``WM`` (masked weight) and *clears* ``W``;
  * any ``dot_general`` / ``conv_general_dilated`` consuming a value still
    tainted ``W`` is an unmasked contraction -> MSK001 (error).

The second half validates concrete mask pytrees: binary values (MSK002)
and exact N:M group counts along the reduction axis (MSK003).
"""
from __future__ import annotations

from typing import Any, Dict, FrozenSet, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import core as jcore

from repro.analysis.findings import Finding
from repro.analysis.jaxpr_utils import _as_jaxpr
from repro.sparsity import sparse_params as SP

Taint = FrozenSet[str]
_EMPTY: Taint = frozenset()
_W: Taint = frozenset({"W"})
_M: Taint = frozenset({"M"})
_WM: Taint = frozenset({"WM"})

_CONTRACTIONS = ("dot_general", "conv_general_dilated")
_SUBJAXPR_KEYS = ("jaxpr", "call_jaxpr", "fun_jaxpr")
_MAX_FIXPOINT = 8


def _taint_jaxpr(
    jaxpr,
    in_taints: Sequence[Taint],
    sink: Dict[Tuple[str, str], Finding],
    where: str,
    config: str,
) -> List[Taint]:
    """Propagate taints through one jaxpr; returns outvar taints. Findings
    are deduplicated into ``sink`` (fixpoint iterations revisit eqns)."""
    jaxpr = _as_jaxpr(jaxpr)
    env: Dict[Any, Taint] = {}

    def read(atom) -> Taint:
        if isinstance(atom, jcore.Literal):
            return _EMPTY
        return env.get(atom, _EMPTY)

    def write(var, taint: Taint) -> None:
        if not isinstance(var, jcore.DropVar):
            env[var] = taint

    if len(jaxpr.invars) != len(in_taints):
        raise ValueError(
            f"{where}: taint arity mismatch "
            f"({len(jaxpr.invars)} invars, {len(in_taints)} taints)"
        )
    for v, t in zip(jaxpr.invars, in_taints):
        write(v, t)
    for v in jaxpr.constvars:
        write(v, _EMPTY)

    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        ts = [read(a) for a in eqn.invars]
        union: Taint = frozenset().union(*ts) if ts else _EMPTY

        sub_out = _dispatch_subjaxpr(eqn, ts, sink, where, config)
        if sub_out is not None:
            for v, t in zip(eqn.outvars, sub_out):
                write(v, t)
            continue

        if name in _CONTRACTIONS:
            for pos, t in enumerate(ts):
                if "W" in t:
                    key = ("MSK001", f"{where}:{name}#{pos}")
                    sink.setdefault(
                        key,
                        Finding(
                            code="MSK001",
                            severity="error",
                            pass_name="masks",
                            config=config,
                            location=where,
                            message=(
                                f"unmasked prunable weight reaches a {name} "
                                f"(operand {pos}) — pruned slots would receive "
                                "nonzero gradient; multiply by the frozen mask "
                                "before the contraction (apply_masks)"
                            ),
                        ),
                    )
            out_t = union
        elif name == "mul" and "W" in union and "M" in union:
            # the mask multiply: W is neutralized, the product is masked
            out_t = (union - {"W", "M"}) | {"WM"}
        else:
            out_t = union

        for v in eqn.outvars:
            write(v, out_t)

    return [read(v) for v in jaxpr.outvars]


def _dispatch_subjaxpr(eqn, ts, sink, where, config):
    """Handle call/control-flow primitives; returns outvar taints or None
    for plain primitives."""
    name = eqn.primitive.name
    params = eqn.params

    if name == "scan":
        sub = _as_jaxpr(params["jaxpr"])
        nc, ncar = params["num_consts"], params["num_carry"]
        cur = list(ts)
        out = [_EMPTY] * len(eqn.outvars)
        for _ in range(_MAX_FIXPOINT):
            out = _taint_jaxpr(sub, cur, sink, f"{where}/scan", config)
            new_carry = [cur[nc + i] | out[i] for i in range(ncar)]
            if new_carry == cur[nc:nc + ncar]:
                break
            cur[nc:nc + ncar] = new_carry
        return out

    if name == "while":
        cond = _as_jaxpr(params["cond_jaxpr"])
        body = _as_jaxpr(params["body_jaxpr"])
        cn, bn = params["cond_nconsts"], params["body_nconsts"]
        cond_consts, body_consts = ts[:cn], ts[cn:cn + bn]
        carry = list(ts[cn + bn:])
        for _ in range(_MAX_FIXPOINT):
            _taint_jaxpr(cond, cond_consts + carry, sink, f"{where}/while.cond", config)
            out = _taint_jaxpr(body, body_consts + carry, sink, f"{where}/while.body", config)
            new_carry = [c | o for c, o in zip(carry, out)]
            if new_carry == carry:
                break
            carry = new_carry
        return carry

    if name == "cond":
        outs = None
        for bi, br in enumerate(params["branches"]):
            o = _taint_jaxpr(_as_jaxpr(br), ts[1:], sink, f"{where}/cond.{bi}", config)
            outs = o if outs is None else [a | b for a, b in zip(outs, o)]
        return outs

    for key in _SUBJAXPR_KEYS:
        if key in params and params[key] is not None:
            sub = _as_jaxpr(params[key])
            if len(sub.invars) == len(ts):
                return _taint_jaxpr(sub, ts, sink, f"{where}/{name}", config)
            # unknown calling convention: be conservative, union everything
            union = frozenset().union(*ts) if ts else _EMPTY
            return [union] * len(eqn.outvars)

    return None


# ---------------------------------------------------------------------------
# entry points
# ---------------------------------------------------------------------------
def check_masked_fn(
    fn, weights, masks, *args, where: str = "block_loss", config: str = ""
) -> List[Finding]:
    """Trace ``fn(weights, masks, *args)`` and verify no prunable weight
    reaches a contraction unmasked. ``weights`` / ``masks`` are matching
    pytrees (masks as produced by the pruning layer: full-shape on
    prunable leaves, scalar elsewhere)."""
    closed = jax.make_jaxpr(fn)(weights, masks, *args)

    w_flat = jax.tree_util.tree_flatten_with_path(weights)[0]
    prunable = [SP.is_prunable(path, leaf) for path, leaf in w_flat]
    m_flat = jax.tree_util.tree_leaves(masks)
    if len(m_flat) != len(prunable):
        raise ValueError("weights and masks pytrees do not match")

    taints: List[Taint] = []
    taints += [_W if p else _EMPTY for p in prunable]
    taints += [_M if p else _EMPTY for p in prunable]
    rest = jax.tree_util.tree_leaves(args)
    taints += [_EMPTY] * len(rest)

    sink: Dict[Tuple[str, str], Finding] = {}
    _taint_jaxpr(closed.jaxpr, taints, sink, where, config)
    return list(sink.values())


def check_mask_tree(
    masks, params, *, nm: Tuple[int, int] = None, config: str = ""
) -> List[Finding]:
    """Validate a concrete mask pytree: binary values everywhere, and (when
    ``nm`` is given) exact N:M group counts along the reduction axis of
    every prunable leaf."""
    findings: List[Finding] = []

    def visit(path, leaf, mask):
        loc = "/".join(SP._path_names(path))
        m = np.asarray(mask)
        if not np.all((m == 0) | (m == 1)):
            findings.append(Finding(
                code="MSK002", severity="error", pass_name="masks",
                config=config, location=loc,
                message="mask values must be exactly {0,1}",
            ))
            return leaf
        if nm is not None and SP.is_prunable(path, leaf):
            n, mm = nm
            name = SP._path_names(path)[-1]
            mat = np.asarray(SP.to_matrix(name, jnp.asarray(m))[0])
            R = mat.shape[-2]
            if R % mm != 0:
                findings.append(Finding(
                    code="MSK004", severity="warn", pass_name="masks",
                    config=config, location=loc,
                    message=f"reduction dim {R} not divisible by M={mm}; "
                            f"N:M pattern not applicable",
                ))
                return leaf
            groups = mat.reshape(*mat.shape[:-2], R // mm, mm, mat.shape[-1]).sum(axis=-2)
            if not np.all(groups == n):
                bad = int((groups != n).sum())
                findings.append(Finding(
                    code="MSK003", severity="error", pass_name="masks",
                    config=config, location=loc,
                    message=f"{bad} group(s) violate the {n}:{mm} pattern "
                            f"(per-group kept counts range "
                            f"{int(groups.min())}..{int(groups.max())})",
                ))
        return leaf

    jax.tree_util.tree_map_with_path(visit, params, masks)
    return findings

"""Pass 1 — Pallas kernel launch validation, statically, per config.

For every config in ``repro.configs`` this derives the matmul / attention
problem shapes its blocks would launch (EBFT tuning uses 8 microbatches of
1024-token calibration segments -> M = 8192 tokens; serving adds the
decode shapes), builds the SAME :class:`~repro.kernels.validation.KernelPlan`
the kernels execute, and reports:

  * KER001 (error) tile does not divide the (clamped) problem shape — the
    kernel would raise at call time, 30 minutes into a calibration run;
  * KER002 (error) per-grid-step VMEM footprint (double-buffered streamed
    blocks + scratch) exceeds the ~16 MiB budget;
  * KER003 (error) BlockSpec index-map arity != grid rank;
  * KER004 (info)  VMEM footprint above 50% of budget (no headroom for
    compiler-allocated temporaries);
  * KER005 (warn)  N:M compression not applicable (reduction dim not a
    multiple of M) — the dense masked_matmul path still works.
"""
from __future__ import annotations

from typing import List, Tuple

from repro.analysis.findings import Finding
from repro.configs.base import ModelConfig
from repro.kernels.validation import (
    VMEM_BUDGET_BYTES,
    pick_tile,
    plan_flash_attention,
    plan_masked_matmul,
    plan_nm_spmm,
)

# EBFT calibration: microbatch of 8 x 1024-token C4 segments (core/ebft.py)
_TUNE_TOKENS = 8 * 1024


def matmul_workloads(
    cfg: ModelConfig, tokens: int = _TUNE_TOKENS
) -> List[Tuple[str, int, int, int]]:
    """(label, M, K, N) for every distinct weight matmul a block launches.

    ``tokens`` is the matmul's M (calibration microbatch x sequence
    length); the default is the paper-scale walk, and the kernel
    autotuner's pre-tune pass (repro.kernels.tuning.ebft_workloads)
    passes the actual run's size.
    """
    d, hd = cfg.d_model, cfg.resolved_head_dim
    H, KV = cfg.num_heads, cfg.num_kv_heads
    M = tokens
    out: List[Tuple[str, int, int, int]] = []

    has_attention = cfg.family != "ssm"
    if has_attention:
        out += [
            ("wq", M, d, H * hd),
            ("wk", M, d, KV * hd),
            ("wv", M, d, KV * hd),
            ("wo", M, H * hd, d),
        ]
    if cfg.family == "moe":
        ff = cfg.moe_d_ff
        out += [("expert_up", M, d, ff), ("expert_down", M, ff, d)]
        if cfg.moe_first_dense > 0 and cfg.d_ff > 0:
            out += [("w_up", M, d, cfg.d_ff), ("w_down", M, cfg.d_ff, d)]
    elif cfg.family in ("ssm", "hybrid"):
        di = cfg.ssm_d_inner
        out += [("in_z", M, d, di), ("in_x", M, d, di), ("ssm_out", M, di, d)]
        if cfg.family == "hybrid" and cfg.d_ff > 0:
            out += [("w_up", M, d, cfg.d_ff), ("w_down", M, cfg.d_ff, d)]
    elif cfg.d_ff > 0:
        out += [("w_up", M, d, cfg.d_ff), ("w_down", M, cfg.d_ff, d)]
    return out


def attention_workloads(cfg: ModelConfig) -> List[Tuple[str, int, int, int]]:
    """(label, Sq, Sk, head_dim) per assigned shape with attention."""
    if cfg.family == "ssm":
        return []
    hd = cfg.resolved_head_dim
    out = []
    for s in cfg.shapes():
        if s.kind == "decode":
            out.append((f"flash/{s.name}", 1, s.seq_len, hd))
        else:
            out.append((f"flash/{s.name}", s.seq_len, s.seq_len, hd))
    return out


# ---------------------------------------------------------------------------
def _vmem_findings(plan, config: str, location: str) -> List[Finding]:
    findings: List[Finding] = []
    used = plan.vmem_bytes()
    if used > VMEM_BUDGET_BYTES:
        findings.append(Finding(
            code="KER002", severity="error", pass_name="kernels",
            config=config, location=location,
            message=(
                f"per-grid-step VMEM {used / 2**20:.1f} MiB exceeds the "
                f"{VMEM_BUDGET_BYTES / 2**20:.0f} MiB budget "
                f"(tiles {plan.tiles})"
            ),
        ))
    elif used > VMEM_BUDGET_BYTES // 2:
        findings.append(Finding(
            code="KER004", severity="info", pass_name="kernels",
            config=config, location=location,
            message=(
                f"per-grid-step VMEM {used / 2**20:.1f} MiB is above 50% of "
                "budget — little headroom for compiler temporaries"
            ),
        ))
    for err in plan.index_map_arity_errors():
        findings.append(Finding(
            code="KER003", severity="error", pass_name="kernels",
            config=config, location=location, message=err,
        ))
    return findings


def check_config_kernels(
    name: str,
    cfg: ModelConfig,
    *,
    nm: Tuple[int, int] = (2, 4),
    tiles: Tuple[int, int, int] = (128, 128, 128),
) -> List[Finding]:
    findings: List[Finding] = []
    bm, bk, bn = tiles
    n, m = nm

    for label, M, K, N in matmul_workloads(cfg):
        # model the tile selection a real launch performs: preferred tile
        # if it divides, else power-of-two halvings — KER001 when the
        # dimension admits no viable tile at all.
        tm, tk, tn = pick_tile(M, bm), pick_tile(K, bk), pick_tile(N, bn)
        bad = [(d, v) for d, v, t in (("M", M, tm), ("K", K, tk), ("N", N, tn))
               if t is None]
        if bad:
            findings.append(Finding(
                code="KER001", severity="error", pass_name="kernels",
                config=name, location=f"masked_matmul/{label}",
                message="; ".join(
                    f"no tile in {{{bm},...,8}} divides {d}={v}" for d, v in bad
                ),
            ))
            continue
        try:
            plan = plan_masked_matmul(M, K, N, bm=tm, bk=tk, bn=tn)
        except ValueError as e:
            findings.append(Finding(
                code="KER001", severity="error", pass_name="kernels",
                config=name, location=f"masked_matmul/{label}",
                message=str(e),
            ))
            continue
        findings += _vmem_findings(plan, name, f"masked_matmul/{label}")

        if K % m != 0:
            findings.append(Finding(
                code="KER005", severity="warn", pass_name="kernels",
                config=name, location=f"nm_spmm/{label}",
                message=(
                    f"reduction dim K={K} not divisible by M={m}; "
                    f"{n}:{m} compression unavailable for this matmul"
                ),
            ))
            continue
        tkg = pick_tile(K, bk, multiple_of=m)
        if tkg is None:
            findings.append(Finding(
                code="KER001", severity="error", pass_name="kernels",
                config=name, location=f"nm_spmm/{label}",
                message=f"no {m}-aligned tile in {{{bk},...,8}} divides K={K}",
            ))
            continue
        try:
            nplan = plan_nm_spmm(M, K, N, n=n, m=m, bm=tm, bk=tkg, bn=tn)
        except ValueError as e:
            findings.append(Finding(
                code="KER001", severity="error", pass_name="kernels",
                config=name, location=f"nm_spmm/{label}", message=str(e),
            ))
            continue
        findings += _vmem_findings(nplan, name, f"nm_spmm/{label}")

    for label, Sq, Sk, hd in attention_workloads(cfg):
        tq, tk2 = pick_tile(Sq, bm), pick_tile(Sk, bk)
        if tq is None or tk2 is None:
            findings.append(Finding(
                code="KER001", severity="error", pass_name="kernels",
                config=name, location=label,
                message=f"no tile in {{{bm},...,8}} divides "
                        f"Sq={Sq} / Sk={Sk}",
            ))
            continue
        try:
            fplan = plan_flash_attention(1, Sq, Sk, hd, bq=tq, bk=tk2)
        except ValueError as e:
            findings.append(Finding(
                code="KER001", severity="error", pass_name="kernels",
                config=name, location=label, message=str(e),
            ))
            continue
        findings += _vmem_findings(fplan, name, label)

    return findings

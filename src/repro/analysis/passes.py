"""Pass drivers: wire the four checkers to real configs/models.

The kernel and sharding passes run on the **exact assigned config numbers**
(pure shape math + ``eval_shape``, no compute). The mask and jaxpr passes
need a traced graph, so they trace each config's SMOKE variant — same
family, same code path, tiny shapes — which keeps the full suite well
under the 60 s CPU budget (docs/ANALYSIS.md).
"""
from __future__ import annotations

from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from repro.analysis.config_check import (
    check_ebft_mesh_plan, check_model_config, check_sharding,
)
from repro.analysis.findings import Finding
from repro.analysis.jaxpr_lint import lint_jaxpr
from repro.analysis.kernel_check import check_config_kernels
from repro.analysis.mask_check import check_mask_tree, check_masked_fn
from repro.configs.base import ModelConfig
from repro.core import reconstruction as R
from repro.optim.optimizers import adam, apply_updates
from repro.sparsity import sparse_params as SP

# one traced model per distinct smoke config — many archs alias tiny_*
_MODEL_CACHE: Dict[str, Tuple] = {}


def _smoke_model(smoke_cfg: ModelConfig):
    key = smoke_cfg.name
    if key not in _MODEL_CACHE:
        from repro.models.model import build

        model = build(smoke_cfg)
        params = model.init(jax.random.PRNGKey(0))
        _MODEL_CACHE[key] = (model, params)
    return _MODEL_CACHE[key]


def _block_indices(model) -> List[int]:
    """Block 0 plus one representative of each *other* block kind (MoE
    expert block, hybrid shared-attention block, encdec decoder block)."""
    cfg = model.cfg
    idx = [0]
    if cfg.family == "moe" and cfg.moe_first_dense > 0:
        idx.append(cfg.moe_first_dense)
    if cfg.family == "hybrid":
        idx.append(model.num_blocks - 1)
    if cfg.family == "encdec":
        idx.append(cfg.enc_layers)
    return idx


def _block_io(model, i: int, B: int = 2, S: int = 8):
    cfg = model.cfg
    dt = jnp.dtype(cfg.dtype)
    h = jnp.zeros((B, S, cfg.d_model), dt)
    pos = jnp.arange(S)[None, :]
    aux = {}
    if cfg.family == "encdec" and i >= cfg.enc_layers:
        aux = {"memory": jnp.zeros((B, S, cfg.d_model), dt)}
    return h, pos, aux


# ---------------------------------------------------------------------------
def run_kernel_pass(name: str, cfg: ModelConfig, smoke: ModelConfig) -> List[Finding]:
    return check_config_kernels(name, cfg)


def run_sharding_pass(name: str, cfg: ModelConfig, smoke: ModelConfig) -> List[Finding]:
    findings = check_model_config(name, cfg)
    if not any(f.severity == "error" for f in findings):
        findings += check_sharding(name, cfg, multi_pod=False)
        # the mesh-aware EBFT walk's layouts (production mesh + microbatch)
        findings += check_ebft_mesh_plan(name, cfg)
    return findings


def run_mask_pass(name: str, cfg: ModelConfig, smoke: ModelConfig) -> List[Finding]:
    """Prove Eq.-4 mask dominance on the traced block_loss of every block
    kind, then validate a concrete N:M mask pytree for one block."""
    findings: List[Finding] = []
    model, params = _smoke_model(smoke)

    for i in _block_indices(model):
        bw = model.get_block(params, i)
        masks_b = SP.ones_masks(bw)
        h, pos, aux = _block_io(model, i)

        def loss(bw_, masks_, h_, pos_, i=i, aux=aux):
            return R.block_loss(model, i, bw_, masks_, h_, h_, pos_, aux)

        try:
            findings += check_masked_fn(
                loss, bw, masks_b, h, pos,
                where=f"block_loss[{R.block_kind(model, i)}]", config=name,
            )
        except Exception as e:
            findings.append(Finding(
                code="MSK000", severity="warn", pass_name="masks",
                config=name, location=f"block{i}",
                message=f"could not trace block_loss: {e}",
            ))

    # concrete-pattern validation: build a 2:4 mask for block 0 and check it
    bw = model.get_block(params, 0)

    def make_mask(path, leaf):
        if SP.is_prunable(path, leaf):
            nm_name = SP._path_names(path)[-1]
            mat, tag = SP.to_matrix(nm_name, jnp.abs(leaf))
            if mat.shape[-2] % 4 == 0:
                return SP.from_matrix(SP.nm_mask(mat, 2, 4), tag)
            return jnp.ones(leaf.shape, jnp.float32)
        return jnp.ones((), jnp.float32)

    masks_b = jax.tree_util.tree_map_with_path(make_mask, bw)
    nm_ok = jax.tree_util.tree_map_with_path(
        lambda p, l: (not SP.is_prunable(p, l))
        or SP.to_matrix(SP._path_names(p)[-1], l)[0].shape[-2] % 4 == 0,
        bw,
    )
    if all(jax.tree_util.tree_leaves(nm_ok)):
        findings += check_mask_tree(masks_b, bw, nm=(2, 4), config=name)
    else:
        findings += check_mask_tree(masks_b, bw, nm=None, config=name)
    return findings


def run_jaxpr_pass(name: str, cfg: ModelConfig, smoke: ModelConfig) -> List[Finding]:
    """Lint the EBFT tune step (value_and_grad + Adam update) and the
    serving decode step of the smoke model."""
    findings: List[Finding] = []
    model, params = _smoke_model(smoke)

    # --- tune step (the ebft.tune_block inner step) -----------------------
    i = 0
    bw = model.get_block(params, i)
    masks_b = SP.ones_masks(bw)
    h, pos, aux = _block_io(model, i)
    opt = adam(2e-4)
    opt_state = opt.init(bw)

    def tune_step(bw_, opt_state_, masks_, h_, target_, pos_):
        def loss_fn(b):
            return R.block_loss(model, i, b, masks_, h_, target_, pos_, aux)

        loss, g = jax.value_and_grad(loss_fn)(bw_)
        upd, new_state = opt.update(g, opt_state_, bw_)
        return apply_updates(bw_, upd), new_state, loss

    try:
        closed = jax.make_jaxpr(tune_step)(bw, opt_state, masks_b, h, h, pos)
        findings += lint_jaxpr(closed, where="ebft.tune_step", config=name)
    except Exception as e:
        findings.append(Finding(
            code="LNT000", severity="warn", pass_name="jaxpr",
            config=name, location="ebft.tune_step",
            message=f"could not trace tune step: {e}",
        ))

    # --- serving decode step ---------------------------------------------
    try:
        state = model.init_serve_state(2, 16)
        tok = jnp.zeros((2, 1), jnp.int32)
        closed = jax.make_jaxpr(model.decode_step)(params, tok, state)
        findings += lint_jaxpr(closed, where="serving.decode_step", config=name)
    except Exception as e:
        findings.append(Finding(
            code="LNT000", severity="warn", pass_name="jaxpr",
            config=name, location="serving.decode_step",
            message=f"could not trace decode step: {e}",
        ))
    return findings


PASSES = {
    "kernels": run_kernel_pass,
    "masks": run_mask_pass,
    "jaxpr": run_jaxpr_pass,
    "sharding": run_sharding_pass,
}

"""Analysis pass ``tuning_cache``: validate the autotuner's plan cache.

The kernel autotuner (:mod:`repro.kernels.tuning`) persists winning tile
plans in a JSON cache keyed by shape/dtype/backend/``code_rev``. This
pass replays every entry through the same :mod:`repro.kernels.validation`
plan builders the kernels execute, so a cache that was hand-edited,
produced by different sources, or corrupted by a partial copy fails CI
before it can steer a launch.

Codes (docs/ANALYSIS.md):

  * TUN001 (error) — cached tiles fail KernelPlan validation for the
    entry's own dims (the launch would raise, or the cache was edited);
  * TUN002 (error) — cached plan exceeds the VMEM double-buffering
    budget (would deadlock or spill at launch);
  * TUN003 (warn)  — entry's ``code_rev`` no longer matches the current
    kernel sources: dead weight, re-tune or prune it;
  * TUN004 (error) — malformed file, schema, or entry (missing fields,
    wrong types, unknown kernel).

A missing cache file is not a finding — most checkouts never tune.
"""
from __future__ import annotations

import json
from typing import Any, List, Optional

from repro.analysis.findings import Finding
from repro.kernels import tuning
from repro.kernels.validation import VMEM_BUDGET_BYTES

_PASS = "tuning_cache"
_ENTRY_FIELDS = ("kernel", "dims", "dtypes", "params", "tiles", "code_rev")


def _finding(code: str, severity: str, message: str,
             location: str = "") -> Finding:
    return Finding(code=code, severity=severity, pass_name=_PASS,
                   message=message, location=location)


def _check_entry(key: str, entry: Any, current_rev: str) -> List[Finding]:
    # keys are long ("kernel|dims|dtypes|params|backend|device|rev");
    # point findings at the readable kernel|dims prefix
    loc = "|".join(key.split("|")[:2])
    if not isinstance(entry, dict):
        return [_finding("TUN004", "error",
                         f"entry is {type(entry).__name__}, expected object",
                         loc)]
    missing = [f for f in _ENTRY_FIELDS if f not in entry]
    if missing:
        return [_finding("TUN004", "error",
                         f"entry missing field(s): {', '.join(missing)}",
                         loc)]

    out: List[Finding] = []
    rev = entry["code_rev"]
    if rev != current_rev:
        out.append(_finding(
            "TUN003", "warn",
            f"stale code_rev {rev!r} (current {current_rev!r}): entry can "
            "never hit — re-tune or prune it", loc,
        ))

    kernel, dims, dtypes = entry["kernel"], entry["dims"], entry["dtypes"]
    params, tiles = entry["params"], entry["tiles"]
    if not all(isinstance(x, dict) for x in (dims, dtypes, params, tiles)):
        out.append(_finding("TUN004", "error",
                            "dims/dtypes/params/tiles must be objects", loc))
        return out
    try:
        plan = tuning.build_plan(
            kernel,
            {k: int(v) for k, v in dims.items()},
            {k: str(v) for k, v in dtypes.items()},
            dict(params),
            {k: int(v) for k, v in tiles.items()},
        )
    except ValueError as e:
        out.append(_finding(
            "TUN001", "error",
            f"cached tiles {tiles} rejected by the plan builder: {e}", loc,
        ))
        return out
    except (TypeError, KeyError) as e:
        out.append(_finding(
            "TUN004", "error",
            f"entry fields do not form a plannable launch: "
            f"{type(e).__name__}: {e}", loc,
        ))
        return out

    vmem = plan.vmem_bytes()
    if vmem > VMEM_BUDGET_BYTES:
        out.append(_finding(
            "TUN002", "error",
            f"cached plan needs {vmem} B VMEM, budget is "
            f"{VMEM_BUDGET_BYTES} B — the search never admits this; "
            "the entry was edited or produced by other constraints", loc,
        ))
    return out


def check_cache(path: Optional[str] = None) -> List[Finding]:
    """Validate the plan cache at ``path`` (default: the tuner's current
    cache path). Missing file → no findings; anything unreadable or
    inconsistent → TUN0xx findings."""
    path = path or tuning.state()["path"]
    try:
        with open(path) as f:
            payload = json.load(f)
    except FileNotFoundError:
        return []
    except (OSError, json.JSONDecodeError) as e:
        return [_finding("TUN004", "error",
                         f"cannot load cache: {type(e).__name__}: {e}", path)]

    if not isinstance(payload, dict):
        return [_finding("TUN004", "error",
                         f"cache is {type(payload).__name__}, "
                         "expected object", path)]
    if payload.get("schema") != tuning.SCHEMA:
        return [_finding(
            "TUN004", "error",
            f"cache schema {payload.get('schema')!r}, expected "
            f"{tuning.SCHEMA!r}", path,
        )]
    entries = payload.get("entries")
    if not isinstance(entries, dict):
        return [_finding("TUN004", "error",
                         "cache has no 'entries' object", path)]

    current_rev = tuning.code_rev()
    out: List[Finding] = []
    for key in sorted(entries):
        out.extend(_check_entry(key, entries[key], current_rev))
    return out

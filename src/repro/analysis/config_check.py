"""Pass 4 — config arithmetic and sharding validation.

Checks the pure config math first (CFG0xx: the `d_model % n_heads == 0`
family of invariants), then runs the real sharding rules
(``distributed.sharding.param_pspecs``) against the production
AbstractMeshes and re-verifies every emitted PartitionSpec leaf-by-leaf
(SHD0xx) — axes must exist in the mesh and divide their dimension, the
contract a 7B dry-run would otherwise discover 30 minutes in.

Where post-SPMD HLO text is available (``--hlo-dir``), it is parsed with
``launch.hlo_analysis`` and collective replica groups / while trip counts
are validated too (HLO0xx).
"""
from __future__ import annotations

from typing import Any, List, Optional

import jax

from repro.analysis.findings import Finding
from repro.configs.base import ModelConfig
from repro.distributed import sharding as SH
from repro.launch import hlo_analysis as HA
from repro.launch.mesh import abstract_production_mesh


def check_model_config(name: str, cfg: ModelConfig) -> List[Finding]:
    findings: List[Finding] = []

    def err(code, msg):
        findings.append(Finding(
            code=code, severity="error", pass_name="sharding",
            config=name, location="config", message=msg,
        ))

    if cfg.head_dim == 0 and cfg.num_heads > 0 and cfg.d_model % cfg.num_heads != 0:
        err("CFG001", f"d_model={cfg.d_model} not divisible by "
                      f"num_heads={cfg.num_heads} (and head_dim unset)")
    if cfg.num_kv_heads > 0 and cfg.num_heads % cfg.num_kv_heads != 0:
        err("CFG002", f"num_heads={cfg.num_heads} not divisible by "
                      f"num_kv_heads={cfg.num_kv_heads} (GQA grouping broken)")
    if cfg.family == "moe":
        if cfg.moe_top_k > cfg.moe_num_experts:
            err("CFG003", f"moe_top_k={cfg.moe_top_k} exceeds "
                          f"moe_num_experts={cfg.moe_num_experts}")
        if cfg.moe_d_ff <= 0:
            err("CFG003", "moe family requires moe_d_ff > 0")
        if cfg.moe_first_dense >= cfg.num_layers:
            err("CFG003", f"moe_first_dense={cfg.moe_first_dense} leaves no "
                          f"MoE layers (num_layers={cfg.num_layers})")
    if cfg.family in ("ssm", "hybrid"):
        if cfg.ssm_state <= 0:
            err("CFG004", f"{cfg.family} family requires ssm_state > 0")
        elif cfg.ssm_d_inner % cfg.ssm_head_dim != 0:
            err("CFG004", f"ssm_d_inner={cfg.ssm_d_inner} not divisible by "
                          f"ssm_head_dim={cfg.ssm_head_dim}")
    if cfg.family == "hybrid" and cfg.hybrid_attn_every <= 0:
        err("CFG005", "hybrid family requires hybrid_attn_every > 0")
    if cfg.family == "encdec" and cfg.enc_layers <= 0:
        err("CFG006", "encdec family requires enc_layers > 0")
    if cfg.vocab_size <= 0 or cfg.d_model <= 0 or cfg.num_layers <= 0:
        err("CFG007", "vocab_size, d_model, num_layers must be positive")
    return findings


# ---------------------------------------------------------------------------
def check_sharding(
    name: str, cfg: ModelConfig, *, multi_pod: bool = False
) -> List[Finding]:
    """Run the real sharding rules on the real parameter shapes and verify
    the emitted specs against the production mesh."""
    from repro.models.model import build

    findings: List[Finding] = []
    mesh = abstract_production_mesh(multi_pod=multi_pod)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    try:
        model = build(cfg)
        shapes = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    except Exception as e:  # config too broken to even build
        findings.append(Finding(
            code="SHD000", severity="error", pass_name="sharding",
            config=name, location="build",
            message=f"model build/eval_shape failed: {e}",
        ))
        return findings

    specs = SH.param_pspecs(shapes, mesh, fsdp=False)
    axis_names = set(mesh.axis_names)

    def visit(path, leaf, spec):
        loc = "/".join(SH._path_names(path))
        for d, ax in enumerate(spec):
            if ax is None:
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            size = 1
            for a in axes:
                if a not in axis_names:
                    findings.append(Finding(
                        code="SHD001", severity="error", pass_name="sharding",
                        config=name, location=f"{loc}[{d}]",
                        message=f"PartitionSpec axis {a!r} not in mesh "
                                f"{mesh_name} {sorted(axis_names)}",
                    ))
                size *= SH.mesh_axis_size(mesh, a)
            if size > 1 and leaf.shape[d] % size != 0:
                findings.append(Finding(
                    code="SHD002", severity="error", pass_name="sharding",
                    config=name, location=f"{loc}[{d}]",
                    message=f"dim {leaf.shape[d]} not divisible by mesh "
                            f"extent {size} ({ax})",
                ))
        return leaf

    jax.tree_util.tree_map_with_path(visit, shapes, specs)

    msize = SH.mesh_axis_size(mesh, SH.MODEL_AXIS)
    if cfg.num_heads > 0 and cfg.num_heads % msize != 0:
        findings.append(Finding(
            code="SHD003", severity="warn", pass_name="sharding",
            config=name, location="attention",
            message=f"num_heads={cfg.num_heads} not divisible by model "
                    f"axis {msize}: falls back to zero-padded head "
                    "expansion (launch/steps.py pad_q_heads)",
        ))
    if 0 < cfg.num_kv_heads < msize:
        findings.append(Finding(
            code="SHD004", severity="info", pass_name="sharding",
            config=name, location="attention",
            message=f"num_kv_heads={cfg.num_kv_heads} < model axis {msize}: "
                    "KV projections replicate (standard Megatron GQA fallback)",
        ))
    return findings


# ---------------------------------------------------------------------------
def check_ebft_mesh_plan(
    name: str,
    cfg: ModelConfig,
    *,
    data: int = 16,
    model_axis: int = 16,
    microbatch: int = 256,
    seq: int = 1024,
) -> List[Finding]:
    """Verify the EBFT calibration-walk layouts divide the mesh (SHD005).

    Runs the real :class:`repro.distributed.meshplan.MeshPlan` rules on an
    AbstractMesh — no devices needed. The plan never fails at runtime (a
    non-dividing leaf silently replicates), so the *analysis* pass is
    where the fallback becomes visible: a warn per degraded layout.
    Production values (16x16 mesh, microbatch 256) divide cleanly.
    """
    from repro.distributed.meshplan import MeshPlan
    from repro.launch.mesh import make_abstract_mesh
    from repro.models.model import build

    findings: List[Finding] = []
    mesh = make_abstract_mesh((data, model_axis), ("data", "model"))
    plan = MeshPlan.from_mesh(mesh)

    # stacked calibration streams: dim 1 (per-microbatch batch) over "data"
    if plan.data_size > 1 and microbatch % plan.data_size != 0:
        findings.append(Finding(
            code="SHD005", severity="warn", pass_name="sharding",
            config=name, location="ebft.stacked_stream",
            message=f"microbatch={microbatch} not divisible by data axis "
                    f"{plan.data_size}: calibration streams replicate "
                    "(MeshPlan.stacked_spec fallback — every device holds "
                    "the full batch)",
        ))

    # block weights over "model": any matrix leaf that fell back to full
    # replication loses the one-live-block-per-device memory property
    try:
        m = build(cfg)
        # get_block slices stacked leaves (a[i]), so it must run under the
        # same trace as init — ShapeDtypeStructs are not subscriptable
        block0 = jax.eval_shape(
            lambda: m.get_block(m.init(jax.random.PRNGKey(0)), 0))
    except Exception as e:
        findings.append(Finding(
            code="SHD000", severity="error", pass_name="sharding",
            config=name, location="ebft.build",
            message=f"model build/eval_shape failed: {e}",
        ))
        return findings

    leaves = {
        path: leaf
        for (path, leaf) in (
            ("/".join(str(getattr(k, "key", k)) for k in p), v)
            for p, v in jax.tree_util.tree_flatten_with_path(block0)[0]
        )
    }
    # Reference plan on a unit mesh: every divisibility check passes there,
    # so a leaf sharded on the unit mesh but replicated on the real mesh is
    # exactly the divisibility fallback. Leaves unsharded on BOTH have no
    # sharding rule at all (SSM scan states, conv stacks, routers) — those
    # replicate by design and are not findings.
    unit = MeshPlan.from_mesh(make_abstract_mesh((1, 1), ("data", "model")))
    rule_exists = {p: s for p, _spec, s in unit.explain(block0)}
    degraded = []
    for path, spec, sharded in plan.explain(block0):
        leaf = leaves.get(path)
        if leaf is None or len(getattr(leaf, "shape", ())) < 2:
            continue  # biases/norms replicate by design
        if not sharded and rule_exists.get(path):
            degraded.append(f"{path}{tuple(leaf.shape)}")
    if degraded:
        shown = ", ".join(degraded[:4])
        more = f" (+{len(degraded) - 4} more)" if len(degraded) > 4 else ""
        findings.append(Finding(
            code="SHD005", severity="warn", pass_name="sharding",
            config=name, location="ebft.block0",
            message=f"{len(degraded)} block leaves replicate on the "
                    f"{data}x{model_axis} mesh (param_pspecs divisibility "
                    "fallback; per-shard live-block bytes = full leaf): "
                    f"{shown}{more}",
        ))
    return findings


# ---------------------------------------------------------------------------
def check_hlo_text(
    text: str, total_devices: int, *, source: str = "hlo"
) -> List[Finding]:
    """Validate post-SPMD HLO text with the hlo_analysis parser: replica
    groups must tile the device count, while loops should have recoverable
    trip counts (otherwise roofline totals silently undercount)."""
    findings: List[Finding] = []
    comps = HA.parse_module(text)
    for cname, comp in comps.items():
        for ins in comp.instructions:
            if any(ins.op.startswith(c) for c in HA._COLLECTIVES):
                g = HA.group_size(ins, total_devices)
                if g <= 0 or total_devices % g != 0:
                    findings.append(Finding(
                        code="HLO002", severity="error", pass_name="sharding",
                        location=f"{source}:{cname}/{ins.name}",
                        message=f"collective group size {g} does not tile "
                                f"{total_devices} devices",
                    ))
            if ins.op == "while":
                trip = 0
                mt = HA._KNOWN_TRIP.search(ins.line)
                if mt:
                    trip = int(mt.group(1))
                else:
                    mc = HA._COND.search(ins.line)
                    if mc and mc.group(1) in comps:
                        t = HA._trip_from_condition(comps[mc.group(1)])
                        trip = t if t > 1 else 0
                if trip == 0:
                    findings.append(Finding(
                        code="HLO001", severity="warn", pass_name="sharding",
                        location=f"{source}:{cname}/{ins.name}",
                        message="while loop with unrecoverable trip count — "
                                "roofline totals will undercount this loop",
                    ))
    return findings


def check_hlo_dir(hlo_dir: str, total_devices: int = 256) -> List[Finding]:
    import glob
    import os

    findings: List[Finding] = []
    for path in sorted(
        glob.glob(os.path.join(hlo_dir, "*.txt"))
        + glob.glob(os.path.join(hlo_dir, "*.hlo"))
    ):
        with open(path) as f:
            findings += check_hlo_text(
                f.read(), total_devices, source=os.path.basename(path)
            )
    return findings

"""Finding / Report types shared by every analysis pass.

A :class:`Finding` is one violation (or observation) with a stable code —
codes are what ``--ignore`` silences (docs/ANALYSIS.md lists them all).
Severities: ``error`` (would mis-compute or fail at runtime), ``warn``
(probably wrong or wasteful), ``info`` (worth knowing).
"""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, Iterable, List, Optional

SEVERITIES = ("info", "warn", "error")
_RANK = {s: i for i, s in enumerate(SEVERITIES)}


@dataclasses.dataclass(frozen=True)
class Finding:
    code: str          # stable id, e.g. "KER001"
    severity: str      # "error" | "warn" | "info"
    pass_name: str     # "kernels" | "masks" | "jaxpr" | "sharding"
    message: str
    config: str = ""   # config the finding applies to ("" = config-independent)
    location: str = "" # kernel/leaf/eqn the finding points at

    def __post_init__(self):
        if self.severity not in _RANK:
            raise ValueError(f"unknown severity {self.severity!r}")

    def asdict(self) -> Dict[str, str]:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class Report:
    findings: List[Finding] = dataclasses.field(default_factory=list)
    passes_run: List[str] = dataclasses.field(default_factory=list)
    configs_checked: List[str] = dataclasses.field(default_factory=list)

    def add(self, findings: Iterable[Finding]) -> None:
        self.findings.extend(findings)

    def without(self, ignored_codes: Iterable[str]) -> "Report":
        ignored = set(ignored_codes)
        return dataclasses.replace(
            self, findings=[f for f in self.findings if f.code not in ignored]
        )

    def count(self, severity: str) -> int:
        return sum(1 for f in self.findings if f.severity == severity)

    def max_severity(self) -> Optional[str]:
        if not self.findings:
            return None
        return max((f.severity for f in self.findings), key=_RANK.get)

    def exit_code(self, fail_on: str = "error") -> int:
        """0 when no finding reaches the ``fail_on`` severity."""
        if fail_on == "never":
            return 0
        threshold = _RANK[fail_on]
        return int(any(_RANK[f.severity] >= threshold for f in self.findings))

    # ------------------------------------------------------------------
    def to_json(self) -> str:
        return json.dumps(
            {
                "passes": self.passes_run,
                "configs": self.configs_checked,
                "counts": {s: self.count(s) for s in SEVERITIES},
                "findings": [f.asdict() for f in self.findings],
            },
            indent=2,
        )

    def to_text(self) -> str:
        lines: List[str] = []
        order = sorted(
            self.findings, key=lambda f: (-_RANK[f.severity], f.pass_name, f.code)
        )
        for f in order:
            where = " ".join(x for x in (f.config, f.location) if x)
            lines.append(
                f"{f.severity.upper():5s} {f.code} [{f.pass_name}]"
                + (f" {where}:" if where else "")
                + f" {f.message}"
            )
        counts = ", ".join(f"{self.count(s)} {s}" for s in reversed(SEVERITIES))
        lines.append(
            f"-- {len(self.findings)} finding(s) ({counts}) across "
            f"{len(self.configs_checked)} config(s), "
            f"passes: {', '.join(self.passes_run) or 'none'}"
        )
        return "\n".join(lines)

"""CLI: ``python -m repro.analysis [options]``.

Runs the static-analysis passes over every registered config (or a
subset) and prints severity-ranked findings — human text by default,
``--json`` for machines. Exit code is 1 when any finding reaches the
``--fail-on`` severity (default: error), so shipped configs gate CI.

Examples::

    python -m repro.analysis                       # everything
    python -m repro.analysis --configs llama_7b --passes kernels masks
    python -m repro.analysis --json --fail-on warn --ignore SHD004
    python -m repro.analysis --extra-config-module my_bad_configs
"""
from __future__ import annotations

import argparse
import importlib
import sys
import time

from repro.analysis import PASS_NAMES, run
from repro.configs import ARCH_IDS, EXTRA_IDS


def _load_extra(module_name: str):
    """Import ``module_name`` and return its ``ANALYSIS_CONFIGS`` list of
    (name, ModelConfig) pairs — the hook tests use to seed violations."""
    mod = importlib.import_module(module_name)
    pairs = getattr(mod, "ANALYSIS_CONFIGS", None)
    if pairs is None:
        raise SystemExit(
            f"--extra-config-module: {module_name} has no ANALYSIS_CONFIGS"
        )
    return list(pairs)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Static invariant checks for kernels, masks, jaxprs, "
                    "and sharding (docs/ANALYSIS.md).",
    )
    ap.add_argument("--configs", nargs="*", default=None,
                    metavar="NAME",
                    help=f"config subset (default: all — "
                         f"{', '.join(ARCH_IDS + EXTRA_IDS)})")
    ap.add_argument("--passes", nargs="*", default=None, choices=PASS_NAMES,
                    help="pass subset (default: all six)")
    ap.add_argument("--fail-on", default="error",
                    choices=("error", "warn", "info", "never"),
                    help="minimum severity that makes the exit code "
                         "non-zero (default: error)")
    ap.add_argument("--json", action="store_true",
                    help="emit machine-readable JSON instead of text")
    ap.add_argument("--ignore", action="append", default=[], metavar="CODE",
                    help="silence a finding code (repeatable), e.g. "
                         "--ignore SHD004")
    ap.add_argument("--hlo-dir", default=None, metavar="DIR",
                    help="directory of post-SPMD HLO text dumps "
                         "(*.txt / *.hlo) for the HLO0xx checks")
    ap.add_argument("--total-devices", type=int, default=256,
                    help="device count the HLO dumps were compiled for "
                         "(default: 256 = 16x16 mesh)")
    ap.add_argument("--tuning-cache", default=None, metavar="PATH",
                    help="plan-cache file for the tuning_cache pass "
                         "(default: the autotuner's configured path)")
    ap.add_argument("--extra-config-module", default=None, metavar="MODULE",
                    help="import MODULE and also check its ANALYSIS_CONFIGS "
                         "[(name, ModelConfig), ...]")
    ap.add_argument("-q", "--quiet", action="store_true",
                    help="suppress per-config progress on stderr")
    args = ap.parse_args(argv)

    extra = _load_extra(args.extra_config_module) if args.extra_config_module else None
    progress = None
    if not args.quiet and not args.json:
        progress = lambda s: print(f"  ... {s}", file=sys.stderr)  # noqa: E731

    t0 = time.monotonic()
    try:
        report = run(
            config_names=args.configs,
            passes=args.passes,
            extra_configs=extra,
            hlo_dir=args.hlo_dir,
            total_devices=args.total_devices,
            tuning_cache_path=args.tuning_cache,
            progress=progress,
        ).without(args.ignore)
    except ValueError as e:
        ap.error(str(e))

    if args.json:
        print(report.to_json())
    else:
        print(report.to_text())
        print(f"-- analysis took {time.monotonic() - t0:.1f}s")
    return report.exit_code(args.fail_on)


if __name__ == "__main__":
    sys.exit(main())

"""Pass 3 — jaxpr lint over traced hot paths.

Walks the jaxprs of the EBFT tune step and the serving decode step (any
jaxpr, really) and flags:

  * LNT001 (warn)  silent float widenings outside accumulators: a
    ``convert_element_type`` that widens an inexact dtype (bf16 -> f32)
    whose result feeds anything other than a contraction or reduction —
    the classic "mixed-precision model silently runs its elementwise math
    in f32 and doubles its VMEM/HBM traffic" bug;
  * LNT002 (error) host-sync points inside jit: callbacks / infeed /
    outfeed force a device->host round-trip per step and serialize the
    pipeline (ROADMAP: serve path must stay device-resident);
  * LNT003 (info)  degenerate convert round-trips A -> B -> A — the inner
    cast is lossy (if narrowing) or dead (if not), either way unintended.
"""
from __future__ import annotations

from typing import List

import jax.numpy as jnp
from jax import core as jcore

from repro.analysis.findings import Finding
from repro.analysis.jaxpr_utils import iter_eqns, sub_jaxprs_of, var_consumers, var_producers

_HOST_SYNC = {"infeed", "outfeed"}
# consumers for which a widening convert is an accumulator idiom, not a bug
_ACCUMULATOR_CONSUMERS = {"dot_general", "conv_general_dilated", "reduce_sum",
                          "reduce_max", "reduce_min", "reduce_prod"}


def _is_float(dtype) -> bool:
    return jnp.issubdtype(dtype, jnp.inexact)


def lint_jaxpr(closed_jaxpr, where: str, config: str = "") -> List[Finding]:
    findings: List[Finding] = []
    seen = set()

    def emit(code, severity, loc, message):
        key = (code, loc, message)
        if key in seen:
            return
        seen.add(key)
        findings.append(Finding(
            code=code, severity=severity, pass_name="jaxpr",
            config=config, location=loc, message=message,
        ))

    for jaxpr, eqn in iter_eqns(closed_jaxpr):
        name = eqn.primitive.name
        if name in _HOST_SYNC or "callback" in name:
            emit(
                "LNT002", "error", f"{where}:{name}",
                f"host-sync primitive `{name}` inside a jitted hot path — "
                "forces a device->host round-trip every step",
            )

    # convert analyses need per-jaxpr producer/consumer maps
    visited = set()
    stack = [getattr(closed_jaxpr, "jaxpr", closed_jaxpr)]
    while stack:
        jaxpr = stack.pop()
        if id(jaxpr) in visited:
            continue
        visited.add(id(jaxpr))
        producers = var_producers(jaxpr)
        consumers = var_consumers(jaxpr)
        for eqn in jaxpr.eqns:
            stack.extend(sub_jaxprs_of(eqn))
            if eqn.primitive.name != "convert_element_type":
                continue
            src = eqn.invars[0]
            out = eqn.outvars[0]
            src_dt = src.aval.dtype
            out_dt = out.aval.dtype

            # LNT003: A -> B -> A round-trip
            prod = producers.get(src)
            if (
                prod is not None
                and prod.primitive.name == "convert_element_type"
                and isinstance(prod.invars[0], jcore.Var)
                and prod.invars[0].aval.dtype == out_dt
            ):
                emit(
                    "LNT003", "info", f"{where}:convert",
                    f"degenerate convert round-trip "
                    f"{out_dt.name} -> {src_dt.name} -> {out_dt.name}",
                )

            # LNT001: silent float widening outside accumulators
            if (
                _is_float(src_dt)
                and _is_float(out_dt)
                and out_dt.itemsize > src_dt.itemsize
            ):
                cons = consumers.get(out, [])
                if cons and all(
                    c.primitive.name in _ACCUMULATOR_CONSUMERS for c in cons
                ):
                    continue
                emit(
                    "LNT001", "warn", f"{where}:convert",
                    f"silent float widening {src_dt.name} -> {out_dt.name} "
                    "outside an accumulator — elementwise math runs at the "
                    "wider dtype and doubles memory traffic",
                )
    return findings

"""Small helpers for walking jaxprs recursively.

Control-flow and call primitives carry sub-jaxprs in their params under a
handful of conventional keys; ``iter_eqns`` yields every equation in a
closed jaxpr including those nested inside ``pjit``/``scan``/``while``/
``cond``/``remat``/``custom_*`` bodies, together with the jaxpr that owns
it (so per-jaxpr producer maps stay consistent).
"""
from __future__ import annotations

from typing import Any, Iterator, List, Tuple

from jax import core as jcore


def _as_jaxpr(obj) -> Any:
    """ClosedJaxpr -> Jaxpr; Jaxpr passes through."""
    return getattr(obj, "jaxpr", obj)


def sub_jaxprs_of(eqn) -> List[Any]:
    """All sub-jaxprs (as plain Jaxprs) referenced by an equation."""
    out: List[Any] = []
    for key in ("jaxpr", "call_jaxpr", "fun_jaxpr", "cond_jaxpr", "body_jaxpr"):
        sub = eqn.params.get(key)
        if sub is not None:
            out.append(_as_jaxpr(sub))
    for br in eqn.params.get("branches", ()) or ():
        out.append(_as_jaxpr(br))
    return out


def iter_eqns(closed_jaxpr) -> Iterator[Tuple[Any, Any]]:
    """Yield ``(owning_jaxpr, eqn)`` for every equation, depth-first."""
    stack = [_as_jaxpr(closed_jaxpr)]
    seen = set()
    while stack:
        jaxpr = stack.pop()
        if id(jaxpr) in seen:
            continue
        seen.add(id(jaxpr))
        for eqn in jaxpr.eqns:
            yield jaxpr, eqn
            stack.extend(sub_jaxprs_of(eqn))


def var_producers(jaxpr) -> dict:
    """Map each Var to the eqn that produces it (within one jaxpr)."""
    prod = {}
    for eqn in jaxpr.eqns:
        for v in eqn.outvars:
            if not isinstance(v, jcore.DropVar):
                prod[v] = eqn
    return prod


def var_consumers(jaxpr) -> dict:
    """Map each Var to the eqns that consume it (within one jaxpr)."""
    cons: dict = {}
    for eqn in jaxpr.eqns:
        for v in eqn.invars:
            if isinstance(v, jcore.Var):
                cons.setdefault(v, []).append(eqn)
    return cons

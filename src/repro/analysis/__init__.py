"""repro.analysis — static invariant checkers for the EBFT repro.

Six passes, one report (``python -m repro.analysis``; docs/ANALYSIS.md):

  * ``kernels``  — Pallas tile divisibility / VMEM budget / BlockSpec
    arity, against the same :mod:`repro.kernels.validation` plans the
    kernels execute (KER0xx);
  * ``masks``    — taint-based proof that ``reconstruction.block_loss``
    masks every prunable weight before any contraction, plus concrete
    N:M mask-pytree validation (MSK0xx);
  * ``jaxpr``    — lint of the traced EBFT tune step and serving decode
    step: silent widenings, host syncs, convert round-trips (LNT0xx);
  * ``sharding`` — config arithmetic + PartitionSpec-vs-mesh validation,
    and HLO collective/trip-count checks when HLO text is supplied
    (CFG0xx / SHD0xx / HLO0xx);
  * ``source_lint`` — config-independent source hygiene: ``print()`` in
    hot-path packages and non-monotonic ``time.time()`` anywhere in
    ``src/repro`` must go through repro.obs instead (OBS0xx); deprecated
    launcher flags in in-repo callers fail the build (API001 — the
    RunSpec shim exists for users, not for us);
  * ``tuning_cache`` — config-independent validation of the kernel
    autotuner's persistent plan cache: every entry must rebuild through
    the live plan builders, fit the VMEM budget, and match the current
    kernel ``code_rev`` (TUN0xx).

Findings carry stable codes and severities (error/warn/info); the CLI
exit code is governed by ``--fail-on`` and individual codes can be
silenced with ``--ignore CODE``.
"""
from __future__ import annotations

from typing import Iterable, List, Optional, Sequence, Tuple

from repro.analysis.findings import SEVERITIES, Finding, Report
from repro.analysis.passes import PASSES
from repro.configs import ARCH_IDS, EXTRA_IDS, get_config
from repro.configs.base import ModelConfig

# per-config passes from PASSES, plus the config-independent scans
PASS_NAMES = tuple(PASSES) + ("source_lint", "tuning_cache")

__all__ = [
    "Finding", "Report", "SEVERITIES", "PASS_NAMES",
    "resolve_configs", "run",
]


def resolve_configs(
    names: Optional[Sequence[str]] = None,
) -> List[Tuple[str, ModelConfig, ModelConfig]]:
    """(name, real CONFIG, SMOKE variant) triples for the requested config
    names (default: every registered config)."""
    if not names:
        names = list(ARCH_IDS) + list(EXTRA_IDS)
    out = []
    for name in names:
        try:
            out.append((name, get_config(name), get_config(name, smoke=True)))
        except ModuleNotFoundError:
            raise ValueError(
                f"unknown config {name!r}; available: "
                + ", ".join(ARCH_IDS + EXTRA_IDS)
            ) from None
    return out


def run(
    config_names: Optional[Sequence[str]] = None,
    passes: Optional[Sequence[str]] = None,
    extra_configs: Optional[Iterable[Tuple[str, ModelConfig]]] = None,
    hlo_dir: Optional[str] = None,
    total_devices: int = 256,
    tuning_cache_path: Optional[str] = None,
    progress=None,
) -> Report:
    """Run the requested passes over the requested configs.

    ``extra_configs`` injects (name, cfg) pairs not in the registry (the
    cfg doubles as its own smoke variant — keep injected configs small).
    ``tuning_cache_path`` points the ``tuning_cache`` pass at a specific
    plan-cache file (default: the autotuner's configured path).
    ``progress`` is an optional ``callable(str)`` for per-config status.
    """
    selected = list(passes) if passes else list(PASS_NAMES)
    for p in selected:
        if p not in PASS_NAMES:
            raise ValueError(f"unknown pass {p!r}; available: {PASS_NAMES}")

    triples = resolve_configs(config_names)
    if extra_configs:
        triples += [(name, cfg, cfg) for name, cfg in extra_configs]

    report = Report(passes_run=selected,
                    configs_checked=[t[0] for t in triples])
    per_config = [p for p in selected if p in PASSES]
    for name, cfg, smoke in triples:
        for pname in per_config:
            if progress:
                progress(f"{pname:<9} {name}")
            try:
                report.add(PASSES[pname](name, cfg, smoke))
            except Exception as e:  # a crashed pass is itself a finding
                report.add([Finding(
                    code="ANA000", severity="error", pass_name=pname,
                    config=name, location="internal",
                    message=f"pass crashed: {type(e).__name__}: {e}",
                )])

    if "source_lint" in selected:
        from repro.analysis.source_lint import (
            check_deprecated_flags, check_sources,
        )

        if progress:
            progress("source_lint src/repro")
        try:
            report.add(check_sources())
            report.add(check_deprecated_flags())
        except Exception as e:  # a crashed pass is itself a finding
            report.add([Finding(
                code="ANA000", severity="error", pass_name="source_lint",
                location="internal",
                message=f"pass crashed: {type(e).__name__}: {e}",
            )])

    if "tuning_cache" in selected:
        from repro.analysis.tuning_cache import check_cache

        if progress:
            progress("tuning_cache")
        try:
            report.add(check_cache(tuning_cache_path))
        except Exception as e:  # a crashed pass is itself a finding
            report.add([Finding(
                code="ANA000", severity="error", pass_name="tuning_cache",
                location="internal",
                message=f"pass crashed: {type(e).__name__}: {e}",
            )])

    if hlo_dir and "sharding" in selected:
        from repro.analysis.config_check import check_hlo_dir

        report.add(check_hlo_dir(hlo_dir, total_devices))
    return report

"""Source-hygiene pass: keep ad-hoc I/O, clocks, and host syncs out of
hot paths.

With the observability layer in place (docs/OBSERVABILITY.md), library
code under ``src/repro`` must not reach for ``print()`` or
``time.time()`` directly, and hot loops must not force device→host
round-trips:

  * ``print()`` in a hot-path package (OBS001) bypasses the sink model —
    output is invisible to artifacts and un-silenceable in benchmarks.
    Launch drivers and CLIs are exempt: console text is their job (they
    route it through ``Run.say`` when a run is active).
  * ``time.time()`` anywhere in ``src/repro`` (OBS002) is the wrong
    clock for measurement — it is not monotonic (NTP steps produce
    negative durations). Spans use ``time.perf_counter``; wall-clock
    timestamps belong in the run manifest only.
  * ``float(...)`` / ``np.asarray(...)`` inside a ``for``/``while`` loop
    in a hot-path package (OBS003) is a per-iteration host sync: each
    call blocks the host on the device stream and collapses jax's async
    dispatch into lock-step. Reduce on device and transfer one scalar
    after the loop (docs/PERF.md) — or, where the sync is the point
    (host-side convergence checks, user-requested logging), annotate the
    line or the line above it with ``obs: sync-ok`` and a reason.
  * deprecated launcher flags (API001, **error**): the RunSpec facade
    (``repro.launch.api``) keeps the old CLI spellings alive for users,
    but in-repo callers — tests, CI, benchmarks, docs' runnable examples
    — must use the canonical flags, or the shim's warn-once guarantee
    rots. Lines exercising the shim on purpose annotate
    ``api: deprecated-ok``.

The pass is config-independent: it scans the source tree once per
analysis run, skipping ``repro.obs`` (it *implements* the clocks/sinks)
and ``repro.analysis`` (self-scan). The deprecated-flag scan covers the
whole repo (src/tests/benchmarks/examples/.github) except the shim
itself.
"""
from __future__ import annotations

import os
import re
from typing import List, Optional

from repro.analysis.findings import Finding

# packages where print() / in-loop host syncs are findings; launch/ and
# configs/ are CLIs and declarative tables — console output is legitimate
# there.
HOT_PATH_DIRS = (
    "core", "training", "serving", "kernels", "optim", "sparsity",
    "models", "distributed", "checkpoint", "data",
)

# never scanned: obs implements the sinks/clocks, analysis is this pass.
EXCLUDE_DIRS = ("obs", "analysis")

_PRINT = re.compile(r"(?<![\w.])print\s*\(")
_TIME_TIME = re.compile(r"(?<![\w.])time\.time\s*\(")
_HOST_SYNC = re.compile(r"(?<![\w.])(?:float|np\.asarray)\s*\(")
_LOOP_HEADER = re.compile(r"^\s*(?:for|while)\b.*:")
_SYNC_OK = "obs: sync-ok"


def _code_part(line: str) -> str:
    """Strip a trailing comment (best-effort: ignores '#' inside strings
    only when the line starts as a comment — good enough for a lint)."""
    stripped = line.lstrip()
    if stripped.startswith("#"):
        return ""
    return line


def _scan_file(path: str, rel: str, in_hot_path: bool) -> List[Finding]:
    findings: List[Finding] = []
    try:
        with open(path, encoding="utf-8") as f:
            lines = f.readlines()
    except OSError:
        return findings
    loop_indents: List[int] = []  # indents of the enclosing loop headers
    prev_sync_ok = False
    for lineno, raw in enumerate(lines, start=1):
        line = _code_part(raw)
        if not line.strip():
            prev_sync_ok = prev_sync_ok or _SYNC_OK in raw
            continue
        indent = len(line) - len(line.lstrip())
        while loop_indents and indent <= loop_indents[-1]:
            loop_indents.pop()
        where = f"{rel}:{lineno}"
        if in_hot_path and _PRINT.search(line):
            findings.append(Finding(
                code="OBS001", severity="warn", pass_name="source_lint",
                location=where,
                message="print() in hot-path package; use the obs console "
                        "sink (repro.obs run.say) or a metric instead",
            ))
        if _TIME_TIME.search(line):
            findings.append(Finding(
                code="OBS002", severity="warn", pass_name="source_lint",
                location=where,
                message="time.time() is non-monotonic; use "
                        "time.perf_counter() (or an obs span) for timing",
            ))
        if (in_hot_path and loop_indents and _HOST_SYNC.search(line)
                and _SYNC_OK not in raw and not prev_sync_ok):
            findings.append(Finding(
                code="OBS003", severity="warn", pass_name="source_lint",
                location=where,
                message="float()/np.asarray() inside a loop forces a "
                        "device→host sync per iteration; reduce on device "
                        "and transfer once after the loop, or annotate "
                        "'obs: sync-ok <reason>'",
            ))
        if _LOOP_HEADER.match(line):
            loop_indents.append(indent)
        prev_sync_ok = _SYNC_OK in raw
    return findings


def check_sources(src_root: Optional[str] = None) -> List[Finding]:
    """Scan ``src/repro`` (or ``src_root``) for OBS0xx hygiene findings."""
    if src_root is None:
        src_root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    findings: List[Finding] = []
    for dirpath, dirnames, filenames in os.walk(src_root):
        rel_dir = os.path.relpath(dirpath, src_root)
        top = rel_dir.split(os.sep)[0]
        if top in EXCLUDE_DIRS:
            dirnames[:] = []
            continue
        dirnames[:] = [d for d in dirnames if d != "__pycache__"]
        in_hot_path = top in HOT_PATH_DIRS
        for fname in sorted(filenames):
            if not fname.endswith(".py"):
                continue
            rel = os.path.join("repro", rel_dir, fname) if rel_dir != "." \
                else os.path.join("repro", fname)
            findings.extend(
                _scan_file(os.path.join(dirpath, fname), rel, in_hot_path)
            )
    return findings


# ---------------------------------------------------------------------------
# API001 — deprecated launcher flags in in-repo callers
# ---------------------------------------------------------------------------
_DEPRECATED_OK = "api: deprecated-ok"
_FLAG_SCAN_DIRS = ("src", "tests", "benchmarks", "examples", ".github")
_FLAG_EXTS = (".py", ".yml", ".yaml", ".sh")
# the shim itself is where the old spellings are defined
_FLAG_EXEMPT = (os.path.join("src", "repro", "launch", "api.py"),)


def check_deprecated_flags(repo_root: Optional[str] = None) -> List[Finding]:
    """Fail (severity error) on deprecated launcher flags in repo files.

    Scans the unambiguous spellings in ``repro.launch.api.LINT_DEPRECATED``
    across src/tests/benchmarks/examples/.github; a line that exercises the
    deprecation shim on purpose carries ``api: deprecated-ok``.
    """
    from repro.launch.api import _DEPRECATED, LINT_DEPRECATED

    if repo_root is None:
        # .../src/repro/analysis -> repo root is three levels up
        repo_root = os.path.abspath(
            os.path.join(os.path.dirname(os.path.abspath(__file__)),
                         "..", "..", ".."))
    canonical = {
        old: can
        for table in _DEPRECATED.values()
        for can, old in table.items()
        if old in LINT_DEPRECATED
    }
    pattern = re.compile(
        "(" + "|".join(re.escape(f) for f in LINT_DEPRECATED) + r")(?![\w-])"
    )
    findings: List[Finding] = []
    for top in _FLAG_SCAN_DIRS:
        base = os.path.join(repo_root, top)
        if not os.path.isdir(base):
            continue
        for dirpath, dirnames, filenames in os.walk(base):
            dirnames[:] = [d for d in dirnames if d != "__pycache__"]
            for fname in sorted(filenames):
                if not fname.endswith(_FLAG_EXTS):
                    continue
                path = os.path.join(dirpath, fname)
                rel = os.path.relpath(path, repo_root)
                if rel in _FLAG_EXEMPT:
                    continue
                try:
                    with open(path, encoding="utf-8") as f:
                        lines = f.readlines()
                except OSError:
                    continue
                for lineno, raw in enumerate(lines, start=1):
                    m = pattern.search(raw)
                    if not m or _DEPRECATED_OK in raw:
                        continue
                    old = m.group(1)
                    findings.append(Finding(
                        code="API001", severity="error",
                        pass_name="source_lint",
                        location=f"{rel}:{lineno}",
                        message=f"deprecated launcher flag {old}; use "
                                f"{canonical.get(old, 'the canonical flag')} "
                                "(or annotate 'api: deprecated-ok' when "
                                "testing the shim)",
                    ))
    return findings

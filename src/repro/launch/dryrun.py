import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: prove every (architecture x input-shape x mesh) cell
lowers, SPMD-partitions, and compiles for the production meshes, and
extract the roofline terms from the compiled artifact.

The two lines ABOVE the docstring are load-bearing: jax locks the device
count at first initialization, so the 512 placeholder CPU devices must be
requested before ANY jax import (including transitive ones).

Usage:
    python -m repro.launch.dryrun                       # full 40-cell sweep, both meshes
    python -m repro.launch.dryrun --arch qwen1_5_4b --shape train_4k --mesh single
    python -m repro.launch.dryrun --tag fsdp_off --fsdp off ...   # perf variants

Each cell writes experiments/dryrun/<tag>/<arch>__<shape>__<mesh>.json with:
    memory_analysis   (per-device argument/output/temp bytes)
    cost_analysis     (XLA's flops/bytes — understates scanned loops; kept
                       for reference)
    hlo_stats         (trip-count-weighted FLOPs / HBM-proxy bytes /
                       collective wire bytes — see launch/hlo_analysis.py)
    roofline          (three terms, bottleneck, useful ratio, fraction)
"""
import json
import time
import traceback
from typing import Optional

import jax

from repro.configs import get_config, list_configs
from repro.launch import hlo_analysis as HA
from repro.launch import rooflines as RL
from repro.launch import steps as ST
from repro.launch.mesh import make_production_mesh
from repro.obs import metrics as OM
from repro.obs import trace as OT


def run_cell(
    arch: str,
    shape_name: str,
    mesh_name: str,
    out_dir: str,
    fsdp: Optional[bool] = None,
    microbatches: Optional[int] = None,
    skip_existing: bool = False,
    assume_flash: bool = False,
    ebft_dp: bool = False,
) -> dict:
    path = os.path.join(out_dir, f"{arch}__{shape_name}__{mesh_name}.json")
    if skip_existing and os.path.exists(path):
        with open(path) as f:
            rec = json.load(f)
        if "error" not in rec:
            print(f"[skip] {arch} {shape_name} {mesh_name} (cached)")
            return rec

    cfg = get_config(arch)
    if shape_name == "ebft_block":
        shape = ST.EBFT_SHAPE  # the paper's own workload (Alg. 1 inner step)
    else:
        shape = next(s for s in cfg.shapes() if s.name == shape_name)
    mesh = make_production_mesh(multi_pod=(mesh_name == "multi"))
    chips = mesh.devices.size
    rec = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name, "chips": chips,
        "kind": shape.kind,
    }
    t0 = time.perf_counter()
    try:
        if shape.kind == "train":
            cell = ST.build_cell(cfg, shape, mesh, fsdp=fsdp, microbatches=microbatches)
        elif shape.kind == "ebft":
            cell = ST.build_ebft_cell(cfg, shape, mesh, dp_only=ebft_dp)
        else:
            cell = ST.build_cell(cfg, shape, mesh)
        with mesh, OT.span("dryrun/cell", arch=arch, shape=shape_name,
                           mesh=mesh_name):
            lowered = ST.lower_cell(cell)
            t_lower = time.perf_counter() - t0
            compiled = lowered.compile()
            t_compile = time.perf_counter() - t0 - t_lower
        if OT.enabled():
            OM.gauge(f"dryrun/{arch}__{shape_name}__{mesh_name}/lower_s").set(t_lower)
            OM.gauge(
                f"dryrun/{arch}__{shape_name}__{mesh_name}/compile_s"
            ).set(t_compile)

        ma = compiled.memory_analysis()
        rec["memory_analysis"] = {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
            "peak_bytes_est": ma.argument_size_in_bytes + ma.temp_size_in_bytes,
        }
        ca = compiled.cost_analysis() or {}
        rec["cost_analysis"] = {
            "flops": float(ca.get("flops", 0.0)),
            "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
        }
        vmem = None
        if assume_flash:
            c = cell.cfg
            qc = c.attn_q_chunk or shape.seq_len
            vmem = {(qc, c.attn_chunk), (c.attn_chunk, c.attn_chunk),
                    (qc, qc), (1, c.attn_chunk)}
            rec["assume_flash"] = True
        stats = HA.analyze(compiled.as_text(), chips, vmem_score_shapes=vmem)
        rec["hlo_stats"] = stats.asdict()
        roof = RL.terms(stats, cell.cfg, shape, chips)
        rec["roofline"] = roof.asdict()
        rec["timing"] = {"lower_s": t_lower, "compile_s": t_compile}
        rec["fsdp"] = bool(ST.wants_fsdp(cell.cfg)) if fsdp is None else fsdp
        print(
            f"[ok]   {arch:24s} {shape_name:12s} {mesh_name:6s} "
            f"comp={roof.compute_s*1e3:9.2f}ms mem={roof.memory_s*1e3:9.2f}ms "
            f"coll={roof.collective_s*1e3:9.2f}ms -> {roof.bottleneck:10s} "
            f"frac={roof.roofline_fraction:.3f} "
            f"hbm/dev={rec['memory_analysis']['peak_bytes_est']/2**30:.1f}GiB "
            f"(compile {t_compile:.0f}s)",
            flush=True,
        )
    except Exception as e:  # noqa: BLE001 — record and continue the sweep
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
        print(f"[FAIL] {arch} {shape_name} {mesh_name}: {rec['error']}", flush=True)

    os.makedirs(out_dir, exist_ok=True)
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def main(argv=None) -> None:
    from repro.launch.api import RunSpec

    spec = RunSpec.from_argv("dryrun", argv)
    archs = list_configs() if spec.arch == "all" else spec.arch.split(",")
    meshes = ["single", "multi"] if spec.mesh == "both" else [spec.mesh]
    fsdp = None if spec.fsdp == "auto" else (spec.fsdp == "on")
    mb = spec.microbatches or None
    out_dir = os.path.join(spec.out, spec.tag)

    failures = 0
    for arch in archs:
        cfg = get_config(arch)
        shape_names = (
            [s.name for s in cfg.shapes()] if spec.shape == "all"
            else spec.shape.split(",")
        )
        for shape_name in shape_names:
            for mesh_name in meshes:
                rec = run_cell(
                    arch, shape_name, mesh_name, out_dir,
                    fsdp=fsdp, microbatches=mb,
                    skip_existing=spec.skip_existing,
                    assume_flash=spec.assume_flash,
                    ebft_dp=spec.ebft_dp,
                )
                failures += int("error" in rec)
    print(f"\ndry-run complete; failures={failures}")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()

"""Batched serving driver: prefill + decode with continuous batching.

    python -m repro.launch.serve --arch tiny_dense --requests 12 \
        --batch 4 --prompt-len 32 --max-new 16 [--sparse 0.5]

``--sparse`` prunes the (randomly initialised or checkpointed) model with
Wanda and serves the sparse weights — demonstrating that EBFT-fine-tuned
sparse params drop into the serving path unchanged (same pytree).
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.checkpoint import ckpt as CK
from repro.configs import get_config
from repro.core.masks import prune
from repro.data.tokens import CorpusConfig, SyntheticCorpus, calibration_set
from repro.models.model import build
from repro.obs import metrics as OM
from repro.obs.run import start_run
from repro.serving.decode import Request, Server


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tiny_dense")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--sparse", type=float, default=0.0)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--no-obs", action="store_true",
                    help="disable observability (no artifact, no metrics)")
    ap.add_argument("--bench-out", default="",
                    help="optional run-artifact path (JSON summary)")
    args = ap.parse_args()

    run = None
    if not args.no_obs:
        run = start_run("serve", config=args.arch,
                        sparsity=args.sparse or None,
                        extra_manifest={"batch_slots": args.batch,
                                        "requests": args.requests})

    cfg = get_config(args.arch)
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    if args.ckpt_dir:
        latest = CK.latest_step(args.ckpt_dir)
        if latest is not None:
            params = CK.restore(args.ckpt_dir, {"params": params})["params"]
            print(f"loaded checkpoint step {latest}")

    corpus = SyntheticCorpus(CorpusConfig(vocab_size=cfg.vocab_size, seed=args.seed))
    if args.sparse > 0:
        calib = calibration_set(corpus, 16, args.prompt_len)
        _, params = prune(model, params, calib, method="wanda", sparsity=args.sparse)
        print(f"serving wanda-pruned weights at sparsity {args.sparse}")

    rng = np.random.default_rng(args.seed)
    reqs = [
        Request(uid=i, prompt=corpus.sample(rng, args.prompt_len),
                max_new=args.max_new)
        for i in range(args.requests)
    ]
    server = Server(model, params, batch_size=args.batch,
                    max_len=args.max_len, temperature=args.temperature)
    t0 = time.perf_counter()
    results = server.serve(reqs)
    dt = time.perf_counter() - t0
    toks = sum(len(v) for v in results.values())
    print(f"served {len(results)} requests, {toks} tokens in {dt:.1f}s "
          f"({toks / max(dt, 1e-9):.1f} tok/s, continuous batching over "
          f"{args.batch} slots)")
    for uid in sorted(results)[:3]:
        print(f"  req {uid}: {results[uid][:8]}...")
    if run is not None:
        occ = OM.summary().get("serve/batch_occupancy", {})
        print(f"  mean batch occupancy "
              f"{(occ.get('mean') or 0.0) * 100:.0f}% over {args.batch} slots")
        run.finish(extra={"served": {"requests": len(results), "tokens": toks,
                                     "tokens_per_s": toks / max(dt, 1e-9)}},
                   summary_path=args.bench_out or None)


if __name__ == "__main__":
    main()

"""Batched serving driver: prefill + decode with continuous batching.

    python -m repro.launch.serve --arch tiny_dense --requests 12 \
        --slots 4 --prompt-len 32 --max-new 16 [--sparse 0.5]

``--sparse`` prunes the (randomly initialised or checkpointed) model with
Wanda and serves the sparse weights — demonstrating that EBFT-fine-tuned
sparse params drop into the serving path unchanged (same pytree).

Flags are one view of :class:`repro.launch.api.RunSpec`; ``--slots``
names the continuous-batching decode slots (the old ``--batch`` spelling
parses through the deprecation shim).
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.checkpoint import ckpt as CK
from repro.configs import get_config
from repro.core.masks import prune
from repro.data.tokens import CorpusConfig, SyntheticCorpus, calibration_set
from repro.launch.api import RunSpec
from repro.models.model import build
from repro.obs import metrics as OM
from repro.serving.decode import Request, Server


def main(argv=None) -> None:
    spec = RunSpec.from_argv("serve", argv)
    run = spec.start_obs_run()

    cfg = get_config(spec.arch)
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(spec.seed))
    if spec.ckpt_dir:
        latest = CK.latest_step(spec.ckpt_dir)
        if latest is not None:
            params = CK.restore(spec.ckpt_dir, {"params": params})["params"]
            print(f"loaded checkpoint step {latest}")

    corpus = SyntheticCorpus(CorpusConfig(vocab_size=cfg.vocab_size, seed=spec.seed))
    if spec.sparse > 0:
        calib = calibration_set(corpus, 16, spec.prompt_len)
        _, params = prune(model, params, calib, method="wanda", sparsity=spec.sparse)
        print(f"serving wanda-pruned weights at sparsity {spec.sparse}")

    rng = np.random.default_rng(spec.seed)
    reqs = [
        Request(uid=i, prompt=corpus.sample(rng, spec.prompt_len),
                max_new=spec.max_new)
        for i in range(spec.requests)
    ]
    server = Server(model, params, batch_size=spec.slots,
                    max_len=spec.max_len, temperature=spec.temperature)
    t0 = time.perf_counter()
    results = server.serve(reqs)
    dt = time.perf_counter() - t0
    toks = sum(len(v) for v in results.values())
    print(f"served {len(results)} requests, {toks} tokens in {dt:.1f}s "
          f"({toks / max(dt, 1e-9):.1f} tok/s, continuous batching over "
          f"{spec.slots} slots)")
    for uid in sorted(results)[:3]:
        print(f"  req {uid}: {results[uid][:8]}...")
    if run is not None:
        occ = OM.summary().get("serve/batch_occupancy", {})
        print(f"  mean batch occupancy "
              f"{(occ.get('mean') or 0.0) * 100:.0f}% over {spec.slots} slots")
        run.finish(extra={"served": {"requests": len(results), "tokens": toks,
                                     "tokens_per_s": toks / max(dt, 1e-9)}},
                   summary_path=spec.bench_out or None)


if __name__ == "__main__":
    main()

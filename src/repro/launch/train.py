"""End-to-end training driver.

Runs a real (allocating) training loop on whatever devices exist — the
same code path scales from the 1-CPU container (tiny/small configs, the
quickstart) to a pod slice (assigned configs): the mesh is sized from
``jax.device_count()`` and every step is the sharded step from
launch/steps.py.

    python -m repro.launch.train --arch tiny_dense --steps 200 \
        --batch 32 --seq 128 --ckpt-dir /tmp/ckpt

Fault tolerance in action: if ``--ckpt-dir`` has a checkpoint, training
RESUMES from it (elastic: the restore reshards to the current mesh). Kill
the process mid-run and relaunch to exercise it.

Flags are one view of :class:`repro.launch.api.RunSpec`; the mesh axes
are ``--mesh-data``/``--mesh-model`` (the old spellings parse through
the deprecation shim).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import ckpt as CK
from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.data.tokens import CorpusConfig, SyntheticCorpus
from repro.distributed import sharding as SH
from repro.launch import steps as ST
from repro.launch.api import RunSpec
from repro.launch.mesh import make_debug_mesh
from repro.models.model import build
from repro.obs.profile import profiled
from repro.optim.optimizers import adamw
from repro.optim.schedules import warmup_cosine
from repro.training.train_loop import Trainer, make_train_step


def main(argv=None) -> None:
    spec = RunSpec.from_argv("train", argv)
    run = spec.start_obs_run()

    cfg = get_config(spec.arch)
    model = build(cfg)
    ndev = jax.device_count()
    data = spec.mesh_data or (ndev // spec.mesh_model)
    mesh = make_debug_mesh(data, spec.mesh_model)
    print(f"devices={ndev} mesh={dict(zip(mesh.axis_names, mesh.devices.shape))}")

    corpus = SyntheticCorpus(CorpusConfig(vocab_size=cfg.vocab_size, seed=spec.seed))
    shape = ShapeConfig("cli", spec.seq, spec.batch, "train")

    rng = jax.random.PRNGKey(spec.seed)
    with mesh:
        params = model.init(rng)
        pspecs = SH.param_pspecs(params, mesh)
        params = jax.device_put(params, SH.named(pspecs, mesh))
        opt = adamw(warmup_cosine(spec.lr, warmup=20, total=max(spec.steps, 21)))
        opt_state = opt.init(params)

        err_state = None
        step_fn = make_train_step(
            model.loss, opt, microbatches=spec.microbatches,
            compress_ratio=spec.compress,
        )
        if spec.compress < 1.0:
            from repro.optim.grad_compress import init_error_state
            err_state = init_error_state(params)
        # profiled: records compile time vs execution time (no-op when off)
        jitted = profiled(jax.jit(step_fn), "train/step")

        # deterministic data order: batch is a pure function of step, so any
        # host can recompute it after restart (straggler/fault tolerance).
        def data_fn(step: int):
            r = np.random.default_rng((spec.seed << 20) + step)
            toks = np.stack([
                corpus.sample(r, spec.seq) for _ in range(spec.batch)
            ])
            batch = {"tokens": jnp.asarray(toks)}
            if cfg.family == "vlm":
                in_specs = model.input_specs(shape)
                P = in_specs["patches"].shape[1]
                batch["tokens"] = batch["tokens"][:, : spec.seq - P]
                batch["patches"] = jnp.asarray(
                    r.normal(size=(spec.batch, P, cfg.d_model)).astype(np.float32)
                )
            if cfg.family == "encdec":
                F = model.input_specs(shape)["frames"].shape[1]
                batch["frames"] = jnp.asarray(
                    r.normal(size=(spec.batch, F, cfg.d_model)).astype(np.float32)
                )
            return batch

        start = 0
        if spec.ckpt_dir:
            latest = CK.latest_step(spec.ckpt_dir)
            if latest is not None:
                tree = CK.restore(
                    spec.ckpt_dir, {"params": params, "opt_state": opt_state},
                    step=latest,
                )
                params, opt_state = tree["params"], tree["opt_state"]
                start = latest
                print(f"resumed from step {start}")

        trainer = Trainer(
            step_fn=jitted,
            data_fn=data_fn,
            ckpt_dir=spec.ckpt_dir or None,
            ckpt_every=spec.ckpt_every,
            log_every=10,
        )
        t0 = time.perf_counter()
        params, opt_state, history = trainer.run(
            params, opt_state, start, spec.steps - start, err_state
        )
        CK.wait_all()
        dt = time.perf_counter() - t0
        for s, l in history[-5:]:
            print(f"step {s:5d} loss {l:.4f}")
        print(f"{spec.steps - start} steps in {dt:.1f}s "
              f"({(spec.steps - start) / max(dt, 1e-9):.2f} steps/s)")
        if run is not None:
            run.finish(
                extra={"trained": {"steps": spec.steps - start, "seconds": dt,
                                   "steps_per_s": (spec.steps - start) / max(dt, 1e-9),
                                   "history": history}},
                summary_path=spec.bench_out or None,
            )


if __name__ == "__main__":
    main()

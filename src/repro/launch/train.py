"""End-to-end training driver.

Runs a real (allocating) training loop on whatever devices exist — the
same code path scales from the 1-CPU container (tiny/small configs, the
quickstart) to a pod slice (assigned configs): the mesh is sized from
``jax.device_count()`` and every step is the sharded step from
launch/steps.py.

    python -m repro.launch.train --arch tiny_dense --steps 200 \
        --batch 32 --seq 128 --ckpt-dir /tmp/ckpt

Fault tolerance in action: if ``--ckpt-dir`` has a checkpoint, training
RESUMES from it (elastic: the restore reshards to the current mesh). Kill
the process mid-run and relaunch to exercise it.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import ckpt as CK
from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.data.tokens import CorpusConfig, SyntheticCorpus
from repro.distributed import sharding as SH
from repro.launch import steps as ST
from repro.launch.mesh import make_debug_mesh
from repro.models.model import build
from repro.obs.profile import profiled
from repro.obs.run import start_run
from repro.optim.optimizers import adamw
from repro.optim.schedules import warmup_cosine
from repro.training.train_loop import Trainer, make_train_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tiny_dense")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--compress", type=float, default=1.0,
                    help="<1: top-k gradient compression ratio (with error feedback)")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--data", type=int, default=0, help="data-axis size (0=auto)")
    ap.add_argument("--model-axis", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--no-obs", action="store_true",
                    help="disable observability (no artifact, no metrics)")
    ap.add_argument("--bench-out", default="",
                    help="optional run-artifact path (JSON summary)")
    args = ap.parse_args()

    run = None
    if not args.no_obs:
        run = start_run("train", config=args.arch,
                        extra_manifest={"steps": args.steps,
                                        "batch": args.batch, "seq": args.seq})

    cfg = get_config(args.arch)
    model = build(cfg)
    ndev = jax.device_count()
    data = args.data or (ndev // args.model_axis)
    mesh = make_debug_mesh(data, args.model_axis)
    print(f"devices={ndev} mesh={dict(zip(mesh.axis_names, mesh.devices.shape))}")

    corpus = SyntheticCorpus(CorpusConfig(vocab_size=cfg.vocab_size, seed=args.seed))
    shape = ShapeConfig("cli", args.seq, args.batch, "train")

    rng = jax.random.PRNGKey(args.seed)
    with mesh:
        params = model.init(rng)
        pspecs = SH.param_pspecs(params, mesh)
        params = jax.device_put(params, SH.named(pspecs, mesh))
        opt = adamw(warmup_cosine(args.lr, warmup=20, total=max(args.steps, 21)))
        opt_state = opt.init(params)

        err_state = None
        step_fn = make_train_step(
            model.loss, opt, microbatches=args.microbatches,
            compress_ratio=args.compress,
        )
        if args.compress < 1.0:
            from repro.optim.grad_compress import init_error_state
            err_state = init_error_state(params)
        # profiled: records compile time vs execution time (no-op when off)
        jitted = profiled(jax.jit(step_fn), "train/step")

        # deterministic data order: batch is a pure function of step, so any
        # host can recompute it after restart (straggler/fault tolerance).
        def data_fn(step: int):
            r = np.random.default_rng((args.seed << 20) + step)
            toks = np.stack([
                corpus.sample(r, args.seq) for _ in range(args.batch)
            ])
            batch = {"tokens": jnp.asarray(toks)}
            if cfg.family == "vlm":
                spec = model.input_specs(shape)
                P = spec["patches"].shape[1]
                batch["tokens"] = batch["tokens"][:, : args.seq - P]
                batch["patches"] = jnp.asarray(
                    r.normal(size=(args.batch, P, cfg.d_model)).astype(np.float32)
                )
            if cfg.family == "encdec":
                F = model.input_specs(shape)["frames"].shape[1]
                batch["frames"] = jnp.asarray(
                    r.normal(size=(args.batch, F, cfg.d_model)).astype(np.float32)
                )
            return batch

        start = 0
        if args.ckpt_dir:
            latest = CK.latest_step(args.ckpt_dir)
            if latest is not None:
                tree = CK.restore(
                    args.ckpt_dir, {"params": params, "opt_state": opt_state},
                    step=latest,
                )
                params, opt_state = tree["params"], tree["opt_state"]
                start = latest
                print(f"resumed from step {start}")

        trainer = Trainer(
            step_fn=jitted,
            data_fn=data_fn,
            ckpt_dir=args.ckpt_dir or None,
            ckpt_every=args.ckpt_every,
            log_every=10,
        )
        t0 = time.perf_counter()
        params, opt_state, history = trainer.run(
            params, opt_state, start, args.steps - start, err_state
        )
        CK.wait_all()
        dt = time.perf_counter() - t0
        for s, l in history[-5:]:
            print(f"step {s:5d} loss {l:.4f}")
        print(f"{args.steps - start} steps in {dt:.1f}s "
              f"({(args.steps - start) / max(dt, 1e-9):.2f} steps/s)")
        if run is not None:
            run.finish(
                extra={"trained": {"steps": args.steps - start, "seconds": dt,
                                   "steps_per_s": (args.steps - start) / max(dt, 1e-9),
                                   "history": history}},
                summary_path=args.bench_out or None,
            )


if __name__ == "__main__":
    main()

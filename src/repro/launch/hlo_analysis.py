"""Static analyzer for post-optimization (SPMD-partitioned) HLO text.

Why this exists: ``compiled.cost_analysis()`` visits every instruction
ONCE — a ``lax.scan`` over 40 layers contributes its body cost a single
time, so FLOPs/bytes/collective counts of scanned models are understated
by the trip count (verified: scan(10 x matmul) reports the FLOPs of 1).
This analyzer re-derives execution-weighted totals from
``compiled.as_text()``:

  1. split the module into computations and index every instruction's
     output shape by name (operands in optimized HLO carry no shapes),
  2. recover each while loop's trip count — preferentially from the
     ``known_trip_count`` backend_config XLA attaches, falling back to
     the compare-with-constant pattern in the condition computation —
     and propagate multipliers through the call graph (nested scans
     multiply, multiple call sites sum),
  3. per instruction, weighted by its computation's multiplier:
       * dot / convolution FLOPs from shapes (2 x prod(out) x contracted),
       * collective "wire bytes" with ring-algorithm factors and the
         replica-group size parsed per op,
       * an HBM-traffic proxy: fusion-boundary operand+output bytes
         (inside a fusion everything stays in registers/VMEM; what
         crosses the boundary is what hits memory).

All sizes are PER-DEVICE (the partitioned module is the per-device
program). The roofline layer (launch/rooflines.py) divides by per-chip
peak numbers directly.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any, Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s2": 1, "s4": 1, "s8": 1, "u2": 1, "u4": 1, "u8": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1,
    "f8e5m2fnuz": 1, "f8e4m3fnuz": 1, "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)


def shape_bytes(shape_str: str) -> int:
    """Total bytes of a possibly-tuple shape string like
    '(bf16[4,128]{1,0}, f32[8])' or 'f32[16,16]{1,0}'."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _prod(xs) -> int:
    out = 1
    for x in xs:
        out *= x
    return out


# ---------------------------------------------------------------------------
@dataclasses.dataclass
class Instruction:
    name: str
    out_shape: str  # raw shape text (may be a tuple)
    op: str
    operands: List[str]  # operand instruction names
    line: str


@dataclasses.dataclass
class Computation:
    name: str
    instructions: List[Instruction]
    shapes: Dict[str, str]  # instr name -> output shape text


# instruction: [ROOT] %name = <shape> opcode(...operands...), attrs
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*"
    r"((?:\([^=]*?\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?))\s*"
    r"([\w\-]+)\((.*)$"
)
_HDR_NAME = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)")
_OPERAND_NAME = re.compile(r"%([\w.\-]+)")


_BLOCK_COMMENT = re.compile(r"/\*.*?\*/")


def parse_module(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in text.splitlines():
        line = _BLOCK_COMMENT.sub("", line)
        stripped = line.strip()
        if stripped.endswith("{") and "->" in stripped and "=" not in stripped.split("->")[0]:
            m = _HDR_NAME.match(stripped)
            if m:
                cur = Computation(m.group(1), [], {})
                comps[cur.name] = cur
            continue
        if stripped == "}":
            cur = None
            continue
        if cur is None:
            continue
        m = _INSTR_RE.match(line)
        if m:
            name, shape, op, rest = m.groups()
            # operand names: %refs before the closing paren of the arg list
            arg_text = rest.split("), ")[0] if "), " in rest else rest.rstrip(")")
            operands = _OPERAND_NAME.findall(arg_text)
            ins = Instruction(name, shape, op, operands, line)
            cur.instructions.append(ins)
            cur.shapes[name] = shape
    return comps


# ---------------------------------------------------------------------------
# trip counts & multipliers
# ---------------------------------------------------------------------------
_KNOWN_TRIP = re.compile(r'"known_trip_count":\s*\{\s*"n"\s*:\s*"?(\d+)"?')
_CALLED = re.compile(r"(?:to_apply|calls)=%?([\w.\-]+)")
_COND = re.compile(r"condition=%?([\w.\-]+)")
_BODY = re.compile(r"body=%?([\w.\-]+)")
_CONST_VAL = re.compile(r"constant\((\d+)\)")


def _trip_from_condition(cond: Computation) -> int:
    """Fallback: find compare-with-constant in the condition (possibly
    inside a wrapped fusion whose operand is a local constant)."""
    consts: Dict[str, int] = {}
    for ins in cond.instructions:
        if ins.op == "constant":
            m = _CONST_VAL.search(ins.line)
            if m:
                consts[ins.name] = int(m.group(1))
    best = 0
    for ins in cond.instructions:
        if ins.op in ("compare", "fusion"):
            for o in ins.operands:
                if o in consts:
                    best = max(best, consts[o])
    return best or 1


def computation_multipliers(comps: Dict[str, Computation]) -> Dict[str, float]:
    """entry=1; while cond/body multiply by trip count; calls inherit;
    multiple call sites sum."""
    edges: Dict[str, List[Tuple[str, float]]] = {c: [] for c in comps}
    for cname, comp in comps.items():
        for ins in comp.instructions:
            if ins.op == "while":
                trip = 0
                m = _KNOWN_TRIP.search(ins.line)
                if m:
                    trip = int(m.group(1))
                cond_m = _COND.search(ins.line)
                body_m = _BODY.search(ins.line)
                if not trip and cond_m and cond_m.group(1) in comps:
                    trip = _trip_from_condition(comps[cond_m.group(1)])
                trip = max(trip, 1)
                for m2 in (cond_m, body_m):
                    if m2 and m2.group(1) in comps:
                        edges[cname].append((m2.group(1), float(trip)))
            else:
                for m2 in _CALLED.finditer(ins.line):
                    if m2.group(1) in comps:
                        edges[cname].append((m2.group(1), 1.0))

    called = {callee for outs in edges.values() for callee, _ in outs}
    roots = [c for c in comps if c not in called] or [next(iter(comps))]

    mult: Dict[str, float] = {c: 0.0 for c in comps}
    for r in roots:
        mult[r] = 1.0
    for _ in range(len(comps) + 1):
        nxt = {c: 0.0 for c in comps}
        for r in roots:
            nxt[r] = 1.0
        for caller, outs in edges.items():
            if mult[caller] <= 0:
                continue
            for callee, w in outs:
                nxt[callee] += mult[caller] * w
        if nxt == mult:
            break
        mult = nxt
    return mult


# ---------------------------------------------------------------------------
# per-op metrics
# ---------------------------------------------------------------------------
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_GROUPS_V1 = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_GROUPS_V2 = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _dims(shape_text: str) -> List[int]:
    m = _SHAPE_RE.search(shape_text)
    if not m:
        return []
    dims = m.group(2)
    return [int(d) for d in dims.split(",")] if dims else []


def dot_flops(ins: Instruction, shapes: Dict[str, str]) -> float:
    """2 x prod(out) x prod(contracted lhs dims)."""
    out_dims = _dims(ins.out_shape)
    if not ins.operands:
        return 0.0
    lhs_shape = shapes.get(ins.operands[0], "")
    lhs = _dims(lhs_shape)
    cm = _CONTRACT_RE.search(ins.line)
    contracted = 1
    if cm and cm.group(1):
        for d in cm.group(1).split(","):
            di = int(d)
            contracted *= lhs[di] if di < len(lhs) else 1
    return 2.0 * _prod(out_dims) * contracted


def conv_flops(ins: Instruction, shapes: Dict[str, str]) -> float:
    """2 x prod(out) x (kernel spatial x in_channels)."""
    out_dims = _dims(ins.out_shape)
    if len(ins.operands) < 2:
        return 0.0
    ker = _dims(shapes.get(ins.operands[1], ""))
    if not ker:
        return 0.0
    k_inner = _prod(ker) / max(ker[-1], 1)  # all but out-feature dim
    return 2.0 * _prod(out_dims) * k_inner


def group_size(ins: Instruction, total_devices: int) -> int:
    m = _GROUPS_V1.search(ins.line)
    if m:
        return len(m.group(1).split(","))
    m = _GROUPS_V2.search(ins.line)
    if m:
        return int(m.group(2))
    return total_devices


def collective_wire_bytes(
    ins: Instruction, kind: str, n: int, shapes: Dict[str, str]
) -> Tuple[int, int]:
    """(raw payload bytes, ring-algorithm wire-bytes estimate) per device."""
    out_b = shape_bytes(ins.out_shape)
    in_b = sum(shape_bytes(shapes.get(o, "")) for o in ins.operands)
    if n <= 1:
        return out_b, 0
    f = (n - 1) / n
    if kind == "all-reduce":
        return out_b, int(2 * f * out_b)
    if kind == "all-gather":
        return out_b, int(f * out_b)  # each device receives (n-1)/n of out
    if kind == "reduce-scatter":
        return in_b, int(f * in_b)
    if kind == "all-to-all":
        return out_b, int(f * out_b)
    if kind == "collective-permute":
        return out_b, out_b
    return out_b, out_b


# ---------------------------------------------------------------------------
@dataclasses.dataclass
class HLOStats:
    dot_flops: float = 0.0
    conv_flops: float = 0.0
    hbm_bytes: float = 0.0            # fusion-boundary traffic proxy
    collective_payload: float = 0.0   # raw payload bytes
    collective_wire: float = 0.0      # ring-estimate wire bytes
    by_collective: Dict[str, float] = dataclasses.field(default_factory=dict)
    by_group_size: Dict[int, float] = dataclasses.field(default_factory=dict)
    collective_count: float = 0.0

    @property
    def flops(self) -> float:
        return self.dot_flops + self.conv_flops

    def asdict(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        d["flops"] = self.flops
        d["by_group_size"] = {str(k): v for k, v in self.by_group_size.items()}
        return d


# ops whose operand/output traffic crosses a fusion boundary (≈ HBM)
_MEM_OPS = {
    "fusion", "dot", "convolution", "copy", "gather", "scatter",
    "dynamic-update-slice", "dynamic-slice", "sort", "reduce",
    "concatenate", "transpose", "custom-call", "select-and-scatter",
    "cholesky", "triangular-solve", "rng", "reduce-window",
}


def analyze(
    text: str,
    total_devices: int,
    vmem_score_shapes: Optional[set] = None,
) -> HLOStats:
    """``vmem_score_shapes``: set of (q_chunk, kv_chunk) pairs. When given,
    ops whose output's trailing two dims match a pair (the online-softmax
    score pipeline) are treated as VMEM-resident — the memory model of the
    flash-attention Pallas kernel (kernels/flash_attention), which fuses
    scores -> softmax -> PV inside one kernel so those tensors never touch
    HBM on the TPU target. The portable chunked-jnp lowering that the CPU
    dry-run compiles materializes them at fusion boundaries, which
    OVERSTATES the TPU memory term; this flag reports the kernel-true
    number. q/k/v/o traffic is still counted (their producing/consuming
    projection ops are unaffected)."""
    comps = parse_module(text)
    mult = computation_multipliers(comps)
    st = HLOStats()

    def is_vmem_resident(shape_text: str) -> bool:
        if not vmem_score_shapes:
            return False
        dims = _dims(shape_text)
        return len(dims) >= 3 and (dims[-2], dims[-1]) in vmem_score_shapes
    # computations called as fusion bodies contribute no memory traffic of
    # their own (they run in-registers); identify them.
    fusion_bodies = set()
    for comp in comps.values():
        for ins in comp.instructions:
            if ins.op == "fusion":
                m = _CALLED.search(ins.line)
                if m:
                    fusion_bodies.add(m.group(1))
    for cname, comp in comps.items():
        w = mult.get(cname, 0.0)
        if w <= 0:
            continue
        in_fusion = cname in fusion_bodies
        for ins in comp.instructions:
            op = ins.op
            base = None
            for c in _COLLECTIVES:
                if op == c or op == c + "-start":
                    base = c
                    break
            if base is not None:
                n = group_size(ins, total_devices)
                payload, wire = collective_wire_bytes(ins, base, n, comp.shapes)
                st.collective_payload += w * payload
                st.collective_wire += w * wire
                st.by_collective[base] = st.by_collective.get(base, 0.0) + w * wire
                st.by_group_size[n] = st.by_group_size.get(n, 0.0) + w * wire
                st.collective_count += w
                continue
            if op == "dot":
                st.dot_flops += w * dot_flops(ins, comp.shapes)
            elif op == "convolution":
                st.conv_flops += w * conv_flops(ins, comp.shapes)
            if not in_fusion and op in _MEM_OPS:
                if is_vmem_resident(ins.out_shape):
                    continue
                st.hbm_bytes += w * (
                    shape_bytes(ins.out_shape)
                    + sum(
                        shape_bytes(comp.shapes.get(o, ""))
                        for o in ins.operands
                        if not is_vmem_resident(comp.shapes.get(o, ""))
                    )
                )
    return st

"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state — the dry-run must set
XLA_FLAGS before any jax initialization.

Single pod: (16, 16) = 256 chips, axes ("data", "model").
Multi-pod:  (2, 16, 16) = 512 chips, axes ("pod", "data", "model") — the
"pod" axis composes with "data" for batch/gradient parallelism (the
cross-pod all-reduce rides DCN) and with FSDP param sharding for the
trillion-param configs.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(data: int = 1, model: int = 1):
    """Tiny mesh for CPU tests (requires <= available devices)."""
    return jax.make_mesh((data, model), ("data", "model"))


def make_abstract_mesh(shape, axis_names):
    """Device-free mesh for sharding-rule checks (tests, repro.analysis).

    The ``AbstractMesh`` constructor changed across jax releases:
    newer versions take ``(axis_sizes, axis_names)``, 0.4.x takes a single
    ``((name, size), ...)`` tuple. Try the new form first.
    """
    from jax.sharding import AbstractMesh

    try:
        return AbstractMesh(tuple(shape), tuple(axis_names))
    except TypeError:
        return AbstractMesh(tuple(zip(axis_names, shape)))


def abstract_production_mesh(*, multi_pod: bool = False):
    """AbstractMesh twin of ``make_production_mesh`` (no devices needed)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_abstract_mesh(shape, axes)

"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state — the dry-run must set
XLA_FLAGS before any jax initialization.

Single pod: (16, 16) = 256 chips, axes ("data", "model").
Multi-pod:  (2, 16, 16) = 512 chips, axes ("pod", "data", "model") — the
"pod" axis composes with "data" for batch/gradient parallelism (the
cross-pod all-reduce rides DCN) and with FSDP param sharding for the
trillion-param configs.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(data: int = 1, model: int = 1):
    """Tiny mesh for CPU tests (requires <= available devices)."""
    return jax.make_mesh((data, model), ("data", "model"))


def make_ebft_plan(data: int = 0, model: int = 1):
    """MeshPlan for the EBFT calibration walk (docs/DISTRIBUTED.md).

    ``data=0`` sizes the data axis to use every device not taken by the
    model axis; ``data=1, model=1`` (the CLI default) returns the inactive
    single-device plan, keeping the non-mesh path bit-for-bit unchanged.
    On CPU the 8-fake-device repro is::

        XLA_FLAGS=--xla_force_host_platform_device_count=8 \
            python -m repro.launch.ebft_run --mesh-data 4 --mesh-model 2 ...
    """
    from repro.distributed.meshplan import MeshPlan

    ndev = jax.device_count()
    model = max(int(model), 1)
    if data == 0:
        data = max(ndev // model, 1)
    data = max(int(data), 1)
    if data * model == 1:
        return MeshPlan.single()
    if data * model > ndev:
        raise ValueError(
            f"mesh ({data} data x {model} model) needs {data * model} "
            f"devices but only {ndev} exist — set XLA_FLAGS="
            f"--xla_force_host_platform_device_count={data * model} for a "
            "CPU repro, or shrink the axes"
        )
    return MeshPlan.from_mesh(make_debug_mesh(data, model))


def make_abstract_mesh(shape, axis_names):
    """Device-free mesh for sharding-rule checks (tests, repro.analysis).

    The ``AbstractMesh`` constructor changed across jax releases:
    newer versions take ``(axis_sizes, axis_names)``, 0.4.x takes a single
    ``((name, size), ...)`` tuple. Try the new form first.
    """
    from jax.sharding import AbstractMesh

    try:
        return AbstractMesh(tuple(shape), tuple(axis_names))
    except TypeError:
        return AbstractMesh(tuple(zip(axis_names, shape)))


def abstract_production_mesh(*, multi_pod: bool = False):
    """AbstractMesh twin of ``make_production_mesh`` (no devices needed)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_abstract_mesh(shape, axes)

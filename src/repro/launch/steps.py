"""Sharded step builders shared by dryrun / train / serve.

For each (arch config x shape cell x mesh) this module constructs the
jit-able step function plus the in/out shardings and the abstract
(ShapeDtypeStruct) inputs needed to ``.lower().compile()`` it without
allocating anything — the multi-pod dry-run contract.

Three step kinds, matching the assignment's shape semantics:

  * train    — loss + grad (microbatched lax.scan) + optimizer update.
  * prefill  — one full-prompt forward filling the KV cache (inference).
  * decode   — ONE new token against a seq_len-deep KV cache.

Production numerics: bf16 params/activations, f32 optimizer moments
(ZeRO-1-sharded over "data"), block remat for train, chunked attention
(the portable analogue of the flash-attention Pallas kernel) for the
32k/500k cells.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.distributed import act_sharding as AS
from repro.distributed import fsdp as FSDP
from repro.distributed import sharding as SH
from repro.models.model import Model, build
from repro.optim.optimizers import adamw
from repro.training.train_loop import make_train_step

Pytree = Any


# ---------------------------------------------------------------------------
# production adaptation of an assigned config to a shape cell
# ---------------------------------------------------------------------------
# Archs whose TP-sharded bf16 params + f32 grads + ZeRO-1 moments exceed one
# v5e chip's HBM: shard params over (pod, data) too (FSDP, with the ZeRO-3
# gather-at-use policy from distributed/fsdp.py); scan-over-layers pipelines
# the per-layer all-gathers with compute.
#
# Threshold calibration (perf iteration q32b-1): 32B TP-16 fits —
# params 65GB/16 = 4.1GB + grads f32 8.1GB + ZeRO-1 moments 1GB ≈ 13GB
# < 16GB HBM, so FSDP (and its gather traffic) is pure overhead below
# ~60B params.
_FSDP_PARAM_THRESHOLD = 60e9  # params


def padded_heads(cfg: ModelConfig, model_axis: int) -> Tuple[int, int]:
    """Zero-padded head expansion: the smallest (H', KV') >= (H, KV) that
    restores head-parallel attention on a ``model_axis``-wide mesh.

    Semantics-preserving: padded q heads get zero wq/wo rows, so the
    model function is EXACTLY the 40-head model (a tiny-scale allclose
    test pins this; padded-head grads are masked in the update). For GQA
    the pad goes inside each kv group so the q->kv mapping of real heads
    is unchanged; for MHA both H and KV pad together.

    Measured motivation (baseline dry-run): non-divisible heads fall back
    to replicated attention -> the (H, q, k) score pipeline runs FULL-
    width on every model shard (16x the compute and HBM traffic of its
    fair share on qwen2.5-32b / qwen1.5-4b).
    """
    h, kv = cfg.num_heads, cfg.num_kv_heads
    if h % model_axis == 0 or h == 0:
        return h, kv
    if h == kv:  # MHA: pad both together
        h2 = -(-h // model_axis) * model_axis
        if h2 / h <= 1.7:
            return h2, h2
        return h, kv
    group = h // kv
    g2 = group
    while (kv * g2) % model_axis and g2 < 4 * group:
        g2 += 1
    if (kv * g2) % model_axis == 0 and (g2 / group) <= 1.7:
        return kv * g2, kv
    return h, kv


def adapt_config(
    cfg: ModelConfig, shape: ShapeConfig, mesh: Optional[Mesh] = None
) -> ModelConfig:
    """The production numerics/attention policy for a shape cell."""
    kw: Dict[str, Any] = dict(dtype="bfloat16", param_dtype="bfloat16")
    if shape.kind == "train":
        kw["remat"] = "block"
        # 4k train: chunked attention keeps the per-microbatch score
        # buffer at (H, q_chunk, chunk) instead of (H, S, S).
        kw["attn_impl"] = "chunked"
        kw["attn_chunk"] = 1024
        kw["attn_q_chunk"] = 1024
    else:
        kw["attn_impl"] = "chunked"
        kw["attn_chunk"] = 2048
        kw["attn_q_chunk"] = 2048 if shape.seq_len > 8192 else 0
    # head padding pays where the attention score pipeline is hot (train /
    # prefill / ebft). Decode is memory-bound on KV-cache reads: MHA padding
    # (20->32 kv heads) grows the cache 1.6x for zero compute benefit
    # (measured: qwen4b decode memory term 1.85 -> 2.93 s) — skip it there.
    if (mesh is not None and cfg.family not in ("ssm",)
            and shape.kind != "decode"):
        msize = SH.mesh_axis_size(mesh, SH.MODEL_AXIS)
        h2, kv2 = padded_heads(cfg, msize)
        if (h2, kv2) != (cfg.num_heads, cfg.num_kv_heads):
            kw["num_heads"] = h2
            kw["num_kv_heads"] = kv2
            kw["head_dim"] = cfg.resolved_head_dim  # keep hd fixed under pad
    if mesh is not None and cfg.moe_num_experts:
        # per-shard MoE dispatch: G = batch shards makes the (G, E, C, d)
        # dispatch buffer shard (data, EP, ., .) with LOCAL capacity — with
        # G=1 the routing one-hot/cumsum is O(total tokens x E) PER DEVICE
        # (411 GiB/dev on kimi prefill_32k; the measured pathology).
        gshards = 1
        for a in SH.batch_axes(mesh):
            gshards *= SH.mesh_axis_size(mesh, a)
        kw["moe_dispatch_groups"] = gshards
    return cfg.replace(**kw)


def microbatches_for(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh) -> int:
    """Grad-accumulation depth: keep ~1 sample per data-shard per microbatch
    for the 4k cells (bounds live activations; remat bounds within-block)."""
    if shape.kind != "train":
        return 1
    dp = 1
    for a in SH.batch_axes(mesh):
        dp *= SH.mesh_axis_size(mesh, a)
    per_shard = max(1, shape.global_batch // max(dp, 1))
    return per_shard  # microbatch = 1 sample / shard


def wants_fsdp(cfg: ModelConfig) -> bool:
    return cfg.param_count() > _FSDP_PARAM_THRESHOLD


# ---------------------------------------------------------------------------
@dataclasses.dataclass
class SteppedCell:
    """Everything needed to lower/compile one dry-run cell."""

    kind: str  # train | prefill | decode
    fn: Callable  # the pure step function
    in_shardings: Tuple
    out_shardings: Any
    abstract_args: Tuple  # ShapeDtypeStructs matching fn's positional args
    donate_argnums: Tuple[int, ...]
    model: Model
    cfg: ModelConfig


def _named(tree, mesh):
    return SH.named(tree, mesh)


def _abstractify(tree):
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree
    )


# ---------------------------------------------------------------------------
def build_train_cell(
    cfg: ModelConfig,
    shape: ShapeConfig,
    mesh: Mesh,
    *,
    lr: float = 1e-4,
    fsdp: Optional[bool] = None,
    microbatches: Optional[int] = None,
) -> SteppedCell:
    cfg = adapt_config(cfg, shape, mesh)
    model = build(cfg)
    fsdp = wants_fsdp(cfg) if fsdp is None else fsdp
    mb = microbatches_for(cfg, shape, mesh) if microbatches is None else microbatches

    params_shapes = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    pspecs = SH.param_pspecs(params_shapes, mesh, fsdp=fsdp)
    opt = adamw(lr)
    opt_shapes = jax.eval_shape(opt.init, params_shapes)
    ospecs = SH.opt_pspecs(opt_shapes, pspecs, mesh)

    batch_shapes = model.input_specs(shape)
    bspecs = SH.batch_pspecs(batch_shapes, mesh)

    # pin batch sharding to dim 1 after the (microbatches, local, ...)
    # reshape — otherwise GSPMD may shard the microbatch dim and every
    # device redundantly computes the whole microbatch (see train_loop).
    mb_shardings = jax.tree.map(
        lambda spec: NamedSharding(mesh, P(None, *spec)),
        bspecs, is_leaf=lambda x: isinstance(x, P),
    )

    def constrain(mb_tree):
        return jax.lax.with_sharding_constraint(mb_tree, mb_shardings)

    inner = make_train_step(
        model.loss, opt, microbatches=mb,
        constrain_microbatch=constrain if mb > 1 else None,
    )

    act_pol = AS.make_mesh_policy(mesh)
    if fsdp:
        # ZeRO-3 gather-at-use: re-constrain each scanned block's params to
        # TP-only inside the loop body, forcing GSPMD to all-gather WEIGHTS
        # (params_bytes x 3 per step) instead of partial-summing
        # activation-sized products across the data axis (measured 2000x
        # worse on qwen2.5-32b; see EXPERIMENTS.md §Perf).
        gather = FSDP.make_tp_regather(mesh)

        def train_step(params, opt_state, batch):
            with FSDP.gather_policy(gather), AS.policy(act_pol):
                p, o, metrics, _ = inner(params, opt_state, batch, None)
            return p, o, metrics
    else:
        def train_step(params, opt_state, batch):
            with AS.policy(act_pol):
                p, o, metrics, _ = inner(params, opt_state, batch, None)
            return p, o, metrics

    in_sh = (_named(pspecs, mesh), _named(ospecs, mesh), _named(bspecs, mesh))
    out_sh = (in_sh[0], in_sh[1], None)
    return SteppedCell(
        kind="train",
        fn=train_step,
        in_shardings=in_sh,
        out_shardings=out_sh,
        abstract_args=(params_shapes, opt_shapes, batch_shapes),
        donate_argnums=(0, 1),
        model=model,
        cfg=cfg,
    )


# ---------------------------------------------------------------------------
def _serve_fully_sharded(cfg: ModelConfig, mesh: Mesh) -> bool:
    """Inference params sharded over (pod, data) too, gathered per block:
    TP-only leaves kimi-K2 at 126 GiB/dev (2.06 TB bf16 / 16); fully
    sharded it is 8 GB/dev + one layer's gather in flight."""
    msize = SH.mesh_axis_size(mesh, SH.MODEL_AXIS)
    return cfg.param_count() * 2 / msize > 10e9  # bf16 bytes per TP shard


def build_prefill_cell(
    cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh
) -> SteppedCell:
    cfg = adapt_config(cfg, shape, mesh)
    model = build(cfg)

    params_shapes = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    serve_fsdp = _serve_fully_sharded(cfg, mesh)
    pspecs = SH.param_pspecs(params_shapes, mesh, fsdp=serve_fsdp)

    batch_shapes = model.input_specs(shape)
    bspecs = SH.batch_pspecs(batch_shapes, mesh)

    B = shape.global_batch
    state_shapes = jax.eval_shape(
        lambda: model.init_serve_state(B, shape.seq_len)
    )
    sspecs = SH.cache_pspecs(state_shapes, mesh)

    act_pol = AS.make_mesh_policy(mesh)
    gather = FSDP.make_tp_regather(mesh) if serve_fsdp else None

    def prefill_step(params, batch, state):
        if gather is not None:
            with FSDP.gather_policy(gather), AS.policy(act_pol):
                return model.prefill(params, batch, state)
        with AS.policy(act_pol):
            return model.prefill(params, batch, state)

    in_sh = (_named(pspecs, mesh), _named(bspecs, mesh), _named(sspecs, mesh))
    out_sh = (None, _named(sspecs, mesh))
    return SteppedCell(
        kind="prefill",
        fn=prefill_step,
        in_shardings=in_sh,
        out_shardings=out_sh,
        abstract_args=(params_shapes, batch_shapes, state_shapes),
        donate_argnums=(2,),
        model=model,
        cfg=cfg,
    )


# ---------------------------------------------------------------------------
def build_decode_cell(
    cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh
) -> SteppedCell:
    """One decode step: new token (B, 1) against a KV cache / SSM state of
    depth seq_len (the cache is allocated at seq_len + 1 so the write fits)."""
    cfg = adapt_config(cfg, shape, mesh)
    model = build(cfg)

    params_shapes = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    serve_fsdp = _serve_fully_sharded(cfg, mesh)
    pspecs = SH.param_pspecs(params_shapes, mesh, fsdp=serve_fsdp)

    B = shape.global_batch
    state_shapes = jax.eval_shape(
        lambda: model.init_serve_state(B, shape.seq_len + 1)
    )
    sspecs = SH.cache_pspecs(state_shapes, mesh)
    tok_shape = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    tok_spec = SH.batch_pspecs(tok_shape, mesh)

    act_pol = AS.make_mesh_policy(mesh)
    gather = FSDP.make_tp_regather(mesh) if serve_fsdp else None

    def decode_step(params, token, state):
        if gather is not None:
            with FSDP.gather_policy(gather), AS.policy(act_pol):
                return model.decode_step(params, token, state)
        with AS.policy(act_pol):
            return model.decode_step(params, token, state)

    in_sh = (_named(pspecs, mesh), _named(tok_spec, mesh), _named(sspecs, mesh))
    out_sh = (None, _named(sspecs, mesh))
    return SteppedCell(
        kind="decode",
        fn=decode_step,
        in_shardings=in_sh,
        out_shardings=out_sh,
        abstract_args=(params_shapes, tok_shape, state_shapes),
        donate_argnums=(2,),
        model=model,
        cfg=cfg,
    )


# ---------------------------------------------------------------------------
# the paper's own workload: one Adam step of block-wise reconstruction
# fine-tuning (Alg. 1 inner loop) on the production mesh. D_c per the
# paper: 256 x 1024-token segments; here one full-D_c batch per step,
# sharded over (pod, data); the block's weights/masks/moments are
# TP-sharded exactly like the training cells.
EBFT_SHAPE = ShapeConfig("ebft_block", 1024, 256, "ebft")


def build_ebft_cell(
    cfg: ModelConfig,
    shape: ShapeConfig,
    mesh: Mesh,
    *,
    block_index: Optional[int] = None,
    lr: float = 2e-4,  # the paper's EBFT learning rate
    dp_only: bool = False,
) -> SteppedCell:
    """``dp_only``: exploit the paper's block-locality — one block's
    weights (+f32 moments) fit a single chip for every assigned arch, so
    replicating them and going pure-DP trades the per-layer row-parallel
    activation all-reduces (4 x (B/16, S, d) f32 per step under TP) for
    ONE block-sized gradient all-reduce. Beyond-paper optimization; the
    TP layout is the paper-faithful baseline (same sharding as training).
    """
    from repro.core import reconstruction as R
    from repro.optim.optimizers import adam, apply_updates

    cfg = adapt_config(cfg, shape, mesh).replace(remat="none")
    model = build(cfg)
    if block_index is None:
        # mid-stack block; for enc-dec use an encoder block (decoder blocks
        # additionally need the cross-attention memory stream)
        i = (cfg.enc_layers // 2) if cfg.family == "encdec" else model.num_blocks // 2
    else:
        i = block_index

    bw_shapes = jax.eval_shape(
        lambda: model.get_block(model.init(jax.random.PRNGKey(0)), i)
    )
    block_params = sum(
        int(jnp.prod(jnp.array(x.shape))) for x in jax.tree.leaves(bw_shapes)
    )
    # pure DP only pays when the whole block (+f32 moments) is chip-sized;
    # MoE expert blocks (kimi: 16.9B params) must stay EP/TP-sharded.
    if dp_only and block_params > 500e6:
        dp_only = False
    if dp_only:
        bspecs = jax.tree.map(lambda x: P(*([None] * x.ndim)), bw_shapes)
    else:
        bspecs = SH.param_pspecs(bw_shapes, mesh)
    mask_shapes = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, jnp.dtype(cfg.param_dtype)),
        bw_shapes,
    )
    opt = adam(lr)
    opt_shapes = jax.eval_shape(opt.init, bw_shapes)
    ospecs = SH.opt_pspecs(opt_shapes, bspecs, mesh)
    # ZeRO-2-style gradient sharding: same layout as the moments, so the
    # cross-data grad combine lowers to a reduce-scatter (half the wire of
    # the replicated all-reduce) and the optimizer update runs sharded.
    gspecs = SH.opt_pspecs(bw_shapes, bspecs, mesh)

    B, S = shape.global_batch, shape.seq_len
    d = cfg.d_model
    h_shape = jax.ShapeDtypeStruct((B, S, d), jnp.dtype(cfg.dtype))
    pos_shape = jax.ShapeDtypeStruct((1, S), jnp.int32)
    if dp_only:
        # batch over EVERY mesh axis (weights are replicated)
        all_axes = SH.batch_axes(mesh) + (SH.MODEL_AXIS,)
        hspec = P(all_axes, None, None)
        act_pol = AS.make_mesh_policy(mesh, batch_axes=all_axes)
    else:
        hspec = SH.batch_pspecs(h_shape, mesh)
        act_pol = AS.make_mesh_policy(mesh)
    pspec = P(*([None] * 2))

    def ebft_step(bw, opt_state, mask_bp, h, target, pos):
        with AS.policy(act_pol):
            def loss_fn(bw_):
                return R.block_loss(model, i, bw_, mask_bp, h, target, pos, {})

            loss, g = jax.value_and_grad(loss_fn)(bw)
            # ZeRO-2: combine grad partials straight into the moment
            # sharding — a reduce-scatter (wire = 1x grad bytes) instead
            # of a replicated all-reduce (2x); the Adam update then runs
            # on the shards.
            g = jax.lax.with_sharding_constraint(g, _named(gspecs, mesh))
            upd, opt_state2 = opt.update(g, opt_state, bw)
            # ZeRO-1 moments are data-sharded; the update all-gather back
            # to the replicated/TP params is bf16-safe (params are bf16).
            upd = jax.tree.map(lambda u: u.astype(jnp.bfloat16), upd)
            return apply_updates(bw, upd), opt_state2, loss

    in_sh = (
        _named(bspecs, mesh), _named(ospecs, mesh), _named(bspecs, mesh),
        _named(hspec, mesh), _named(hspec, mesh),
        NamedSharding(mesh, pspec),
    )
    out_sh = (in_sh[0], in_sh[1], None)
    return SteppedCell(
        kind="ebft",
        fn=ebft_step,
        in_shardings=in_sh,
        out_shardings=out_sh,
        abstract_args=(bw_shapes, opt_shapes, mask_shapes, h_shape, h_shape, pos_shape),
        donate_argnums=(0, 1),
        model=model,
        cfg=cfg,
    )


# ---------------------------------------------------------------------------
def build_cell(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh, **kw) -> SteppedCell:
    if shape.kind == "train":
        return build_train_cell(cfg, shape, mesh, **kw)
    if shape.kind == "prefill":
        return build_prefill_cell(cfg, shape, mesh)
    if shape.kind == "ebft":
        return build_ebft_cell(cfg, shape, mesh)
    return build_decode_cell(cfg, shape, mesh)


def lower_cell(cell: SteppedCell):
    """jit + lower with abstract inputs (no allocation)."""
    jitted = jax.jit(
        cell.fn,
        in_shardings=cell.in_shardings,
        out_shardings=cell.out_shardings,
        donate_argnums=cell.donate_argnums,
    )
    return jitted.lower(*cell.abstract_args)

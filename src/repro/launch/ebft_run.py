"""The paper's pipeline as a driver: pretrain (or load) -> prune -> EBFT
-> evaluate, with every baseline selectable.

    python -m repro.launch.ebft_run --arch tiny_dense --pretrain-steps 200 \
        --method wanda --sparsity 0.7 --ebft-lr 1e-2

Compares (per the paper's tables): no fine-tuning, DSnoT, mask-tuning,
LoRA and EBFT on held-out perplexity. On the container this runs the tiny
configs; with real devices the identical driver handles the assigned
archs (the walk is block-streamed, so memory stays one-block-sized —
the paper's 16 GB property).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import ebft, lora, mask_tuning
from repro.core.evaluate import perplexity
from repro.core.masks import prune
from repro.data.tokens import (
    CorpusConfig, SyntheticCorpus, calibration_set, corpus_iterator, eval_set,
)
from repro.models.model import build
from repro.optim.optimizers import adamw
from repro.training.train_loop import make_train_step


def pretrain(model, params, corpus, steps: int, batch: int, seq: int, lr: float):
    opt = adamw(lr)
    step = jax.jit(make_train_step(model.loss, opt))
    opt_state = opt.init(params)
    it = corpus_iterator(corpus, batch=batch, seq_len=seq, seed=1)
    loss = float("nan")
    for i in range(steps):
        params, opt_state, metrics, _ = step(
            params, opt_state, {"tokens": jnp.asarray(next(it))}, None
        )
        loss = float(metrics["loss"])
    print(f"pretrained {steps} steps, final loss {loss:.3f}")
    return params


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tiny_dense")
    ap.add_argument("--pretrain-steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--method", default="wanda",
                    choices=["magnitude", "wanda", "sparsegpt", "dsnot", "flap"])
    ap.add_argument("--sparsity", type=float, default=0.7)
    ap.add_argument("--pattern", default="", help="N:M e.g. 2:4")
    ap.add_argument("--calib-samples", type=int, default=64)
    ap.add_argument("--ebft-lr", type=float, default=1e-2)
    ap.add_argument("--ebft-epochs", type=int, default=10)
    ap.add_argument("--baselines", default="",
                    help="comma list of {dsnot,mask,lora} to also run")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    model = build(cfg)
    corpus = SyntheticCorpus(CorpusConfig(vocab_size=cfg.vocab_size, seed=args.seed))
    params = model.init(jax.random.PRNGKey(args.seed))
    if args.pretrain_steps:
        params = pretrain(model, params, corpus, args.pretrain_steps,
                          args.batch, args.seq, 3e-3)

    calib = calibration_set(corpus, args.calib_samples, args.seq)
    ev = eval_set(corpus, 16, args.seq)
    pattern = tuple(int(x) for x in args.pattern.split(":")) if args.pattern else None

    ppl_dense = perplexity(model, params, ev)
    print(f"dense ppl          {ppl_dense:8.2f}")

    t0 = time.time()
    masks, pruned = prune(model, params, calib, method=args.method,
                          sparsity=args.sparsity, pattern=pattern)
    print(f"{args.method} ppl {' ' * (10 - len(args.method))}"
          f"{perplexity(model, pruned, ev):8.2f}   ({time.time()-t0:.0f}s)")

    t0 = time.time()
    ecfg = ebft.EBFTConfig(lr=args.ebft_lr, epochs=args.ebft_epochs)
    tuned, reports = ebft.finetune(model, params, pruned, masks, calib, ecfg)
    print(f"EBFT ppl           {perplexity(model, tuned, ev):8.2f}   "
          f"({time.time()-t0:.0f}s, {len(reports)} blocks, "
          f"mean E drop {sum(r.loss_before - r.loss_after for r in reports) / max(len(reports), 1):.3e})")

    wants = set(args.baselines.split(",")) if args.baselines else set()
    if "dsnot" in wants:
        t0 = time.time()
        _, ds = prune(model, params, calib, method="dsnot",
                      sparsity=args.sparsity, pattern=pattern,
                      dsnot_init=args.method if args.method != "dsnot" else "wanda")
        print(f"DSnoT ppl          {perplexity(model, ds, ev):8.2f}   ({time.time()-t0:.0f}s)")
    if "mask" in wants:
        t0 = time.time()
        mt, _ = mask_tuning.finetune_masks(model, params, masks,
                                           args.sparsity, calib, pattern=pattern)
        print(f"mask-tune ppl      {perplexity(model, mt, ev):8.2f}   ({time.time()-t0:.0f}s)")
    if "lora" in wants:
        t0 = time.time()
        it = corpus_iterator(corpus, batch=8, seq_len=args.seq, seed=9)
        lr_params = lora.finetune_lora(model, pruned, masks, it,
                                       lora.LoRAConfig(steps=200, lr=1e-3))
        print(f"LoRA ppl           {perplexity(model, lr_params, ev):8.2f}   ({time.time()-t0:.0f}s)")


if __name__ == "__main__":
    main()

"""The paper's pipeline as a driver: pretrain (or load) -> prune -> EBFT
-> evaluate, with every baseline selectable.

    python -m repro.launch.ebft_run --arch tiny_dense --pretrain-steps 200 \
        --method wanda --sparsity 0.7 --lr 1e-2

Compares (per the paper's tables): no fine-tuning, DSnoT, mask-tuning,
LoRA and EBFT on held-out perplexity. On the container this runs the tiny
configs; with real devices the identical driver handles the assigned
archs (the walk is block-streamed, so memory stays one-block-sized —
the paper's 16 GB property).

``--mesh-data``/``--mesh-model`` shard the calibration walk across a
device mesh (docs/DISTRIBUTED.md); the default (1x1) is the bit-for-bit
single-device path. CPU repro of the sharded walk::

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        python -m repro.launch.ebft_run --mesh-data 4 --mesh-model 2 ...

The CLI is one view of :class:`repro.launch.api.RunSpec` — the old
``--ebft-*`` flag spellings still parse through the deprecation shim.

Fully instrumented via repro.obs (docs/OBSERVABILITY.md): every phase is
a span, per-block reconstruction data flows into the metrics registry,
and the run writes a ``BENCH_ebft.json`` artifact (manifest + phases +
per-block losses + peak live-block bytes + per-device dispatch ledger +
collective bytes + perplexities) that ``python -m repro.obs report``
renders. ``--no-obs`` disables all of it; the console output is
identical either way (it is just a sink).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import ebft, lora, mask_tuning
from repro.core.evaluate import perplexity
from repro.core.masks import prune
from repro.data.tokens import (
    CorpusConfig, SyntheticCorpus, calibration_set, corpus_iterator, eval_set,
)
from repro.kernels import tuning
from repro.launch.api import RunSpec
from repro.launch.mesh import make_ebft_plan
from repro.models.model import build
from repro.obs import metrics as OM
from repro.obs import trace as OT
from repro.optim.optimizers import adamw
from repro.training.train_loop import make_train_step


class _phase:
    """A pipeline phase: an obs span when observability is on, and a
    plain monotonic wall-time either way (console timings survive
    ``--no-obs``)."""

    def __init__(self, name: str, **attrs):
        self.span = OT.span(name, **attrs)
        self.duration = 0.0

    def __enter__(self) -> "_phase":
        self._t0 = time.perf_counter()
        self.span.__enter__()
        return self

    def __exit__(self, *exc) -> bool:
        self.span.__exit__(*exc)
        self.duration = time.perf_counter() - self._t0
        return False

    def fence(self, value):
        return self.span.fence(value)


def pretrain(model, params, corpus, steps: int, batch: int, seq: int, lr: float,
             say=print):
    opt = adamw(lr)
    step = jax.jit(make_train_step(model.loss, opt))
    opt_state = opt.init(params)
    it = corpus_iterator(corpus, batch=batch, seq_len=seq, seed=1)
    loss = float("nan")
    for i in range(steps):
        params, opt_state, metrics, _ = step(
            params, opt_state, {"tokens": jnp.asarray(next(it))}, None
        )
        loss = float(metrics["loss"])
        if i % 20 == 0 or i == steps - 1:
            OM.series("pretrain/loss").append(loss, step=i)
    say(f"pretrained {steps} steps, final loss {loss:.3f}")
    return params


def main(argv=None) -> None:
    spec = RunSpec.from_argv("ebft", argv)
    run = spec.start_obs_run()
    say = run.say if run is not None else print

    tuning.configure(mode=spec.kernel_tune,
                     path=spec.kernel_cache or None)
    tuning.reset_stats()

    plan = make_ebft_plan(spec.mesh_data, spec.mesh_model)
    if plan.active:
        say(f"calibration mesh: {plan.describe()['axes']} "
            f"({plan.device_count} devices)")

    cfg = get_config(spec.arch)
    model = build(cfg)
    corpus = SyntheticCorpus(CorpusConfig(vocab_size=cfg.vocab_size, seed=spec.seed))
    params = model.init(jax.random.PRNGKey(spec.seed))
    phases = {}
    ppl = {}

    if spec.kernel_tune != "off":
        # warm the tile-plan cache on the shapes this run's walk launches
        # (docs/PERF.md): in search mode this is where the measured sweeps
        # run — outside the timed hot path; in cache mode it is a free
        # readback whose hit/miss counts land in BENCH_ebft.json
        pat = tuple(int(x) for x in spec.pattern.split(":")) \
            if spec.pattern else None
        with _phase("phase/kernel_tune", mode=spec.kernel_tune) as sp:
            pretuned = tuning.pretune(
                tuning.ebft_workloads(cfg, tokens=8 * spec.seq, seq=spec.seq,
                                      pattern=pat),
                interpret=jax.default_backend() != "tpu",
            )
        phases["kernel_tune"] = sp.duration
        st = tuning.stats()
        say(f"kernel plans: {len(pretuned)} workloads, "
            f"{int(st['hits'])} cached, {int(st['searches'])} searched "
            f"({st['search_s']:.1f}s search)")

    if spec.pretrain_steps:
        with _phase("phase/pretrain", steps=spec.pretrain_steps) as sp:
            params = sp.fence(pretrain(model, params, corpus,
                                       spec.pretrain_steps, spec.batch,
                                       spec.seq, 3e-3, say=say))
        phases["pretrain"] = sp.duration

    calib = calibration_set(corpus, spec.calib_samples, spec.seq)
    ev = eval_set(corpus, 16, spec.seq)
    pattern = tuple(int(x) for x in spec.pattern.split(":")) if spec.pattern else None

    with _phase("phase/eval", what="dense") as sp:
        ppl["dense"] = perplexity(model, params, ev)
    phases["eval_dense"] = sp.duration
    say(f"dense ppl          {ppl['dense']:8.2f}")

    with _phase("phase/prune", method=spec.method,
                 sparsity=spec.sparsity) as sp:
        masks, pruned = prune(model, params, calib, method=spec.method,
                              sparsity=spec.sparsity, pattern=pattern)
        sp.fence(pruned)
    phases["prune"] = sp.duration
    ppl[spec.method] = perplexity(model, pruned, ev)
    say(f"{spec.method} ppl {' ' * (10 - len(spec.method))}"
        f"{ppl[spec.method]:8.2f}   ({phases['prune']:.0f}s)")

    ecfg = ebft.EBFTConfig(lr=spec.lr, epochs=spec.epochs,
                           fused_epochs=not spec.no_fused_epochs,
                           prefetch_depth=spec.prefetch_depth,
                           mesh_plan=plan)
    with _phase("phase/ebft", lr=spec.lr, epochs=spec.epochs) as sp:
        tuned, reports = ebft.finetune(model, params, pruned, masks, calib, ecfg)
        sp.fence(tuned)
    phases["ebft"] = sp.duration
    with _phase("phase/eval", what="ebft") as sp:
        ppl["EBFT"] = perplexity(model, tuned, ev)
    phases["eval_ebft"] = sp.duration
    mean_drop = sum(r.loss_before - r.loss_after for r in reports) \
        / max(len(reports), 1)
    say(f"EBFT ppl           {ppl['EBFT']:8.2f}   "
        f"({phases['ebft']:.0f}s, {len(reports)} blocks, "
        f"mean E drop {mean_drop:.3e})")

    wants = set(spec.baselines.split(",")) if spec.baselines else set()
    if "dsnot" in wants:
        with _phase("phase/baseline", which="dsnot") as sp:
            _, ds = prune(model, params, calib, method="dsnot",
                          sparsity=spec.sparsity, pattern=pattern,
                          dsnot_init=spec.method if spec.method != "dsnot" else "wanda")
            ppl["DSnoT"] = perplexity(model, ds, ev)
        phases["baseline_dsnot"] = sp.duration
        say(f"DSnoT ppl          {ppl['DSnoT']:8.2f}   ({sp.duration:.0f}s)")
    if "mask" in wants:
        with _phase("phase/baseline", which="mask") as sp:
            mt, _ = mask_tuning.finetune_masks(model, params, masks,
                                               spec.sparsity, calib, pattern=pattern)
            ppl["mask-tune"] = perplexity(model, mt, ev)
        phases["baseline_mask"] = sp.duration
        say(f"mask-tune ppl      {ppl['mask-tune']:8.2f}   ({sp.duration:.0f}s)")
    if "lora" in wants:
        with _phase("phase/baseline", which="lora") as sp:
            it = corpus_iterator(corpus, batch=8, seq_len=spec.seq, seed=9)
            lr_params = lora.finetune_lora(model, pruned, masks, it,
                                           lora.LoRAConfig(steps=200, lr=1e-3))
            ppl["LoRA"] = perplexity(model, lr_params, ev)
        phases["baseline_lora"] = sp.duration
        say(f"LoRA ppl           {ppl['LoRA']:8.2f}   ({sp.duration:.0f}s)")

    if run is not None:
        summ = OM.summary()
        peak = summ.get("ebft/live_block_bytes", {}).get("max")
        peak_shard = summ.get(
            "ebft/live_block_bytes_per_shard", {}).get("max")
        tune_max = max((r.dispatches for r in reports), default=0)
        sync_max = max((r.host_syncs for r in reports), default=0)
        fused_all = bool(reports) and all(r.path == "fused" for r in reports)
        path = spec.bench_out
        run.finish(
            extra={
                "phases": phases,
                "blocks": [r.asdict() for r in reports],
                "perplexity": ppl,
                "ebft": {
                    "num_blocks": len(reports),
                    "mean_e_drop": mean_drop,
                    "peak_live_block_bytes": peak,
                    "fused_epochs": not spec.no_fused_epochs,
                    "prefetch_depth": spec.prefetch_depth,
                    "early_stops": {
                        reason: sum(1 for r in reports if r.early_stop == reason)
                        for reason in {r.early_stop for r in reports}
                    },
                },
                # device layout + wire accounting (docs/DISTRIBUTED.md):
                # inactive plans report devices=1 and zero collective bytes
                "mesh": {
                    **plan.describe(),
                    "peak_live_block_bytes_per_shard": peak_shard,
                    "collective_bytes_total": sum(
                        r.collective_bytes for r in reports),
                },
                # dispatch/host-sync accounting (docs/PERF.md): per-block =
                # tune-path dispatches + 2 stream advances (teacher+student)
                # in the fused/stacked walk; device_* = per participating
                # device (one SPMD launch enqueues on every mesh device)
                "dispatch": {
                    "tune_per_block_max": tune_max,
                    "tune_host_syncs_per_block_max": sync_max,
                    "per_block_max": tune_max + (2 if fused_all else 0),
                    "fused_all_blocks": fused_all,
                    "walk_total": summ.get("ebft/walk/dispatches", {}).get("value"),
                    "walk_host_syncs": summ.get(
                        "ebft/walk/host_syncs", {}).get("value"),
                    "device_dispatches_per_block": {
                        str(r.index): r.device_dispatches for r in reports
                    },
                    "tune_device_total": summ.get(
                        "ebft/tune/device_dispatches", {}).get("value"),
                    "walk_device_total": summ.get(
                        "ebft/walk/device_dispatches", {}).get("value"),
                },
                # steady-state phase sums, with first-call (trace+compile)
                # time split out per phase (docs/PERF.md): percentiles of
                # the *_s histograms now reflect the pipeline, not warm-up
                "walk_phases": {
                    **{
                        phase: summ.get(f"ebft/walk/{phase}_s", {}).get("sum")
                        for phase in ("teacher", "tune", "student")
                    },
                    **{
                        f"{phase}_compile": summ.get(
                            f"ebft/walk/{phase}_compile_s", {}).get("sum")
                        for phase in ("teacher", "tune", "student")
                    },
                },
                # tile-plan autotuner accounting (docs/PERF.md): a warm
                # cache run must show misses == searches == 0 and
                # search_s == 0.0 (CI gates this via
                # `obs validate --require-cache-hits`)
                "kernel_tuning": {
                    "mode": spec.kernel_tune,
                    "cache_path": tuning.state()["path"],
                    **tuning.stats(),
                },
            },
            summary_path=path,
        )
        print(f"wrote {path}  (render with: python -m repro.obs report {path})")


if __name__ == "__main__":
    main()

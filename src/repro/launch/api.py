"""RunSpec — the one typed description of a launcher invocation.

Every driver under ``repro.launch`` (ebft_run, train, serve, dryrun) is
constructed from a :class:`RunSpec` instead of its own argparse soup:

    spec = RunSpec.from_argv("ebft", argv)     # CLI -> spec
    run  = spec.start_obs_run()                # obs manifest from the spec
    ...
    manifest_extra = spec.to_manifest()        # BENCH_*.json header
    spec2 = RunSpec.from_manifest(payload["manifest"])  # artifact -> spec

The flag surface stays what it was — ``from_argv`` builds the per-kind
parser from one declarative table — but the *source of truth* for what a
run was is now a value that round-trips: argv -> spec -> manifest -> spec.

Deprecated flags (the pre-RunSpec spellings) still parse through a shim
that stores into the canonical destination and warns ONCE per flag per
process (``DeprecationWarning``). In-repo callers must use the canonical
spellings — the ``repro.analysis`` source lint (API001) fails on the
unambiguous deprecated ones, and this module is the single place the old
spellings are allowed to appear.
"""
from __future__ import annotations

import argparse
import dataclasses
import warnings
from typing import Any, Dict, List, Optional, Sequence, Tuple

KINDS = ("ebft", "train", "serve", "dryrun")

# mirrored from repro.kernels.tuning.MODES (kept literal here so parsing a
# spec never imports the kernels package; test_runspec pins the agreement)
KERNEL_TUNE_MODES = ("off", "cache", "search")

# canonical flag -> deprecated aliases, per kind. ``--batch`` stays
# canonical for ebft/train (it really is a batch size there); serve's old
# ``--batch`` meant decode slots, hence the rename.
_DEPRECATED: Dict[str, Dict[str, str]] = {
    "ebft": {"--lr": "--ebft-lr", "--epochs": "--ebft-epochs"},
    "train": {"--mesh-data": "--data", "--mesh-model": "--model-axis"},
    "serve": {"--slots": "--batch"},
    "dryrun": {},
}

# deprecated spellings unambiguous enough for the source lint (API001) to
# flag anywhere in the repo. ``--data``/``--batch`` are generic words and
# are deliberately excluded.
LINT_DEPRECATED: Tuple[str, ...] = ("--ebft-lr", "--ebft-epochs", "--model-axis")

_WARNED: set = set()


def _reset_deprecation_warnings() -> None:
    """Test hook: make the warn-once shim fire again."""
    _WARNED.clear()


class _DeprecatedFlag(argparse.Action):
    """Stores into the canonical dest; warns once per flag per process."""

    def __init__(self, option_strings, dest, canonical: str = "", **kw):
        kw.setdefault("help", argparse.SUPPRESS)
        super().__init__(option_strings, dest, **kw)
        self.canonical = canonical

    def __call__(self, parser, namespace, values, option_string=None):
        if option_string not in _WARNED:
            _WARNED.add(option_string)
            warnings.warn(
                f"{option_string} is deprecated; use {self.canonical}",
                DeprecationWarning, stacklevel=2,
            )
        setattr(namespace, self.dest, values)


# ---------------------------------------------------------------------------
# the spec
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class RunSpec:
    """Typed superset of every launcher's knobs; ``kind`` picks the view.

    Fields a kind does not use keep their defaults and are omitted from
    its manifest (``to_manifest`` writes only that kind's fields).
    """

    kind: str = "ebft"
    # -- shared ------------------------------------------------------------
    arch: str = "tiny_dense"
    seed: int = 0
    batch: int = 32
    seq: int = 128
    lr: float = 1e-2
    no_obs: bool = False
    bench_out: str = ""
    obs_jsonl: str = ""
    ckpt_dir: str = ""
    # -- mesh (ebft + train) ----------------------------------------------
    mesh_data: int = 0
    mesh_model: int = 1
    # -- ebft --------------------------------------------------------------
    pretrain_steps: int = 200
    method: str = "wanda"
    sparsity: float = 0.7
    pattern: str = ""
    calib_samples: int = 64
    epochs: int = 10
    no_fused_epochs: bool = False
    prefetch_depth: int = 1
    kernel_tune: str = "cache"
    kernel_cache: str = ""
    baselines: str = ""
    # -- train -------------------------------------------------------------
    steps: int = 100
    microbatches: int = 1
    compress: float = 1.0
    ckpt_every: int = 50
    # -- serve -------------------------------------------------------------
    requests: int = 12
    slots: int = 4
    prompt_len: int = 32
    max_new: int = 16
    max_len: int = 128
    sparse: float = 0.0
    temperature: float = 0.0
    # -- dryrun ------------------------------------------------------------
    shape: str = "all"
    mesh: str = "both"
    out: str = "experiments/dryrun"
    tag: str = "baseline"
    fsdp: str = "auto"
    skip_existing: bool = False
    assume_flash: bool = False
    ebft_dp: bool = False

    # -- construction ------------------------------------------------------
    @staticmethod
    def from_argv(kind: str, argv: Optional[Sequence[str]] = None) -> "RunSpec":
        if kind not in KINDS:
            raise ValueError(f"unknown launcher kind {kind!r}; one of {KINDS}")
        ap = build_parser(kind)
        args = ap.parse_args(argv)
        fields = {f.name for f in dataclasses.fields(RunSpec)}
        spec = RunSpec(kind=kind, **{
            k: v for k, v in vars(args).items() if k in fields
        })
        try:
            spec.validate()
        except ValueError as e:
            # a parse-time error with usage, not a deep failure mid-walk
            ap.error(str(e))
        return spec

    def validate(self) -> "RunSpec":
        """Cross-field checks that argparse types can't express; raises
        ``ValueError`` with an actionable message (``from_argv`` converts
        it into the parser's usage error)."""
        if self.kernel_tune not in KERNEL_TUNE_MODES:
            raise ValueError(
                f"--kernel-tune must be one of "
                f"{'/'.join(KERNEL_TUNE_MODES)}, got {self.kernel_tune!r}"
            )
        if self.kind == "ebft" and self.prefetch_depth < 1:
            raise ValueError(
                f"--prefetch-depth must be >= 1 (got {self.prefetch_depth}); "
                "the dispatch-ahead teacher stream needs at least one block "
                "in flight. Strictly serial runs are a library-level mode "
                "(EBFTConfig.prefetch_depth=0), not a launcher flag."
            )
        return self

    @staticmethod
    def from_manifest(manifest: Dict[str, Any]) -> "RunSpec":
        """Rebuild the spec from a BENCH_*.json manifest (round-trip)."""
        spec = manifest.get("run_spec")
        if not isinstance(spec, dict):
            raise ValueError("manifest carries no 'run_spec' section")
        fields = {f.name for f in dataclasses.fields(RunSpec)}
        return RunSpec(**{k: v for k, v in spec.items() if k in fields})

    # -- views -------------------------------------------------------------
    def fields_for_kind(self) -> List[str]:
        return list(_KIND_FIELDS[self.kind])

    def to_manifest(self) -> Dict[str, Any]:
        """Manifest header for obs runs and BENCH_*.json artifacts.

        ``run_spec`` holds every field this kind uses (the round-trip
        payload); the flat legacy keys the existing artifacts/tests read
        (``ebft_lr``, ``seq``, ...) are kept alongside it.
        """
        used = {name: getattr(self, name) for name in _KIND_FIELDS[self.kind]}
        out: Dict[str, Any] = {"run_spec": {"kind": self.kind, **used}}
        if self.kind == "ebft":
            out.update({
                "ebft_lr": self.lr, "ebft_epochs": self.epochs,
                "calib_samples": self.calib_samples, "seq": self.seq,
                "seed": self.seed,
                "fused_epochs": not self.no_fused_epochs,
                "prefetch_depth": self.prefetch_depth,
                "mesh": {"data": self.mesh_data, "model": self.mesh_model},
            })
        elif self.kind == "train":
            out.update({"steps": self.steps, "batch": self.batch,
                        "seq": self.seq})
        elif self.kind == "serve":
            out.update({"batch_slots": self.slots, "requests": self.requests})
        return out

    def start_obs_run(self, name: Optional[str] = None, **kw):
        """``obs.run.start_run`` with this spec as the manifest source.

        Returns None when the spec says ``--no-obs``, so drivers can write
        ``run = spec.start_obs_run()`` unconditionally.
        """
        if self.no_obs:
            return None
        from repro.obs.run import start_run

        base: Dict[str, Any] = {"config": self.arch}
        if self.kind == "ebft":
            base.update(method=self.method, sparsity=self.sparsity,
                        pattern=self.pattern or None,
                        jsonl_path=self.obs_jsonl or None)
        if self.kind == "serve":
            base.update(sparsity=self.sparse or None)
        base["extra_manifest"] = self.to_manifest()
        base.update(kw)
        default_name = "ebft_run" if self.kind == "ebft" else self.kind
        return start_run(name or default_name, **base)


# per-kind field lists (order = CLI help order)
_KIND_FIELDS: Dict[str, Tuple[str, ...]] = {
    "ebft": ("arch", "pretrain_steps", "batch", "seq", "method", "sparsity",
             "pattern", "calib_samples", "lr", "epochs", "no_fused_epochs",
             "prefetch_depth", "kernel_tune", "kernel_cache", "baselines",
             "mesh_data", "mesh_model", "seed", "no_obs", "bench_out",
             "obs_jsonl"),
    "train": ("arch", "steps", "batch", "seq", "lr", "microbatches",
              "compress", "ckpt_dir", "ckpt_every", "mesh_data", "mesh_model",
              "seed", "no_obs", "bench_out"),
    "serve": ("arch", "requests", "slots", "prompt_len", "max_new", "max_len",
              "sparse", "ckpt_dir", "temperature", "seed", "no_obs",
              "bench_out"),
    "dryrun": ("arch", "shape", "mesh", "out", "tag", "fsdp", "microbatches",
               "skip_existing", "assume_flash", "ebft_dp"),
}

# per-kind default overrides (where kinds disagree on a shared field)
_KIND_DEFAULTS: Dict[str, Dict[str, Any]] = {
    "ebft": {"bench_out": "BENCH_ebft.json", "mesh_data": 1},
    "train": {"batch": 16, "lr": 3e-3},
    "serve": {},
    "dryrun": {"arch": "all", "microbatches": 0},
}

# flag metadata where the add_argument call is not derivable from the
# dataclass field alone
_FLAG_KW: Dict[str, Dict[str, Any]] = {
    "method": {"choices": ["magnitude", "wanda", "sparsegpt", "dsnot", "flap"]},
    "pattern": {"help": "N:M e.g. 2:4"},
    "no_fused_epochs": {"help": "run the legacy per-microbatch tune loop "
                                "instead of the fused scanned+donated "
                                "dispatch"},
    "prefetch_depth": {"help": "teacher stream dispatched this many blocks "
                               "ahead of the tuner (must be >= 1; "
                               "EBFTConfig.prefetch_depth=0 is the "
                               "programmatic strictly-serial mode)"},
    "kernel_tune": {"choices": list(KERNEL_TUNE_MODES),
                    "help": "Pallas tile-plan resolution: off = built-in "
                            "128 defaults, cache = use cached plans "
                            "(default), search = measure candidates on a "
                            "miss and persist the winner (docs/PERF.md)"},
    "kernel_cache": {"help": "tile-plan cache path (default "
                             "experiments/kernel_cache.json, or "
                             "$REPRO_KERNEL_CACHE)"},
    "baselines": {"help": "comma list of {dsnot,mask,lora} to also run"},
    "mesh_data": {"help": "data-axis size for the calibration mesh "
                          "(0 = auto, 1x1 = single device)"},
    "mesh_model": {"help": "model-axis size for the calibration mesh"},
    "no_obs": {"help": "disable observability (no artifact, no metrics)"},
    "bench_out": {"help": "run-artifact path (JSON summary)"},
    "obs_jsonl": {"help": "optional JSONL event-stream path"},
    "compress": {"help": "<1: top-k gradient compression ratio "
                         "(with error feedback)"},
    "slots": {"help": "continuous-batching decode slots"},
    "mesh": {"choices": ["single", "multi", "both"]},
    "fsdp": {"choices": ["auto", "on", "off"]},
    "assume_flash": {"help": "memory-model the attention score pipeline as "
                             "VMEM-resident (the Pallas flash kernel's HBM "
                             "traffic) instead of the portable chunked "
                             "path's"},
    "ebft_dp": {"help": "pure-DP layout for ebft_block cells (block-local "
                        "weights replicated; see steps.build_ebft_cell)"},
}


def build_parser(kind: str) -> argparse.ArgumentParser:
    """The canonical parser for one launcher kind, plus deprecated shims."""
    ap = argparse.ArgumentParser(prog=f"python -m repro.launch.{_PROG[kind]}")
    defaults = _KIND_DEFAULTS[kind]
    by_name = {f.name: f for f in dataclasses.fields(RunSpec)}
    for name in _KIND_FIELDS[kind]:
        f = by_name[name]
        flag = "--" + name.replace("_", "-")
        default = defaults.get(name, f.default)
        kw = dict(_FLAG_KW.get(name, {}))
        if f.type in ("bool", bool):
            ap.add_argument(flag, action="store_true", default=default, **kw)
        else:
            typ = {"int": int, "float": float, "str": str}.get(f.type, str) \
                if isinstance(f.type, str) else f.type
            ap.add_argument(flag, type=typ, default=default, **kw)
    # the old spellings: parse, warn once, store canonically
    for canonical, old in _DEPRECATED[kind].items():
        dest = canonical.lstrip("-").replace("-", "_")
        f = by_name[dest]
        typ = {"int": int, "float": float, "str": str}.get(f.type, str) \
            if isinstance(f.type, str) else f.type
        ap.add_argument(old, action=_DeprecatedFlag, canonical=canonical,
                        dest=dest, type=typ)
    return ap


_PROG = {"ebft": "ebft_run", "train": "train", "serve": "serve",
         "dryrun": "dryrun"}

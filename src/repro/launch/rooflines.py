"""Roofline terms from the dry-run's compiled artifact.

Hardware model (TPU v5e-class chip, assignment constants):

    peak bf16 compute   197 TFLOP/s / chip
    HBM bandwidth       819 GB/s / chip
    ICI link bandwidth  ~50 GB/s / link

Terms (seconds per step, PER CHIP — the analyzer works on the partitioned
per-device program, so no extra division by chip count is needed):

    compute    = HLO_FLOPs_per_chip / peak
    memory     = HLO_bytes_per_chip / HBM_bw      (fusion-boundary proxy)
    collective = wire_bytes_per_chip / link_bw    (ring-algorithm estimate)

MODEL_FLOPS is the classic parameter-math lower bound: 6·N·D for training
(fwd + bwd), 2·N·D for inference, with N = active params for MoE. The
ratio MODEL_FLOPS/HLO_FLOPs exposes remat/redundancy waste; the roofline
fraction (useful-compute time / max term) is the headline §Perf score.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

from repro.configs.base import ModelConfig, ShapeConfig

PEAK_FLOPS = 197e12   # bf16 per chip
HBM_BW = 819e9        # bytes/s per chip
ICI_BW = 50e9         # bytes/s per link


def model_flops_per_chip(cfg: ModelConfig, shape: ShapeConfig, chips: int) -> float:
    n = cfg.param_count(active_only=(cfg.family == "moe"))
    # embedding lookups are table reads, not matmul FLOPs; the LM head IS a
    # matmul and is inside param_count. Keep the classic 6ND/2ND convention.
    # enc-dec: the encoder only sees the (seq/8)-long frame stream, so its
    # params process 8x fewer tokens than the decoder's.
    embed = cfg.padded_vocab * cfg.d_model * (1 if cfg.tie_embeddings else 2)
    if cfg.family == "encdec":
        dec_frac = cfg.num_layers / max(cfg.num_layers + cfg.enc_layers, 1)
        n = (n - embed) * (dec_frac + (1 - dec_frac) / 8.0) + embed
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens / chips
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens / chips
    if shape.kind == "ebft":
        # one block's fwd+bwd over the calibration batch (no optimizer/embed)
        n_layers = cfg.num_layers + (cfg.enc_layers or 0)
        n_block = (n - embed) / max(n_layers, 1)
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_block * tokens / chips
    # decode: one token per sequence per step
    return 2.0 * n * shape.global_batch / chips


@dataclasses.dataclass
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    model_flops_per_chip: float
    hlo_flops_per_chip: float
    useful_ratio: float       # MODEL_FLOPS / HLO_FLOPs
    roofline_fraction: float  # useful-compute time / max(terms)

    def asdict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)


def terms(
    stats: Any,  # HLOStats
    cfg: ModelConfig,
    shape: ShapeConfig,
    chips: int,
) -> Roofline:
    compute_s = stats.flops / PEAK_FLOPS
    memory_s = stats.hbm_bytes / HBM_BW
    collective_s = stats.collective_wire / ICI_BW
    names = ("compute", "memory", "collective")
    vals = (compute_s, memory_s, collective_s)
    bottleneck = names[max(range(3), key=lambda i: vals[i])]
    mf = model_flops_per_chip(cfg, shape, chips)
    bound = max(max(vals), 1e-30)
    return Roofline(
        compute_s=compute_s,
        memory_s=memory_s,
        collective_s=collective_s,
        bottleneck=bottleneck,
        model_flops_per_chip=mf,
        hlo_flops_per_chip=stats.flops,
        useful_ratio=mf / max(stats.flops, 1e-30),
        roofline_fraction=(mf / PEAK_FLOPS) / bound,
    )

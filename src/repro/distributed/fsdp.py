"""ZeRO-3-style gather-at-use for FSDP-sharded parameters.

Problem (measured in the baseline dry-run, qwen2.5-32b/110b train_4k):
when FSDP shards a weight's CONTRACTING dim over the data axis, GSPMD
lowers the matmul as partial-sums + an all-reduce of the ACTIVATION-sized
product — for attention that is an f32 (B,H,S,chunk) tensor all-reduced
per chunk per layer per microbatch (~3.4e14 wire bytes/step on
qwen2.5-32b: a 2000x pathology over the weight-gather strategy).

Real ZeRO-3 all-gathers the WEIGHTS just-in-time instead: gather traffic
= params_bytes x (fwd + bwd + remat) per step, independent of batch. This
module gives the model scan bodies a hook to express exactly that:

    def body(h, bp):
        bp = fsdp.gather_block(bp)   # no-op unless a policy is active
        ...

The launcher (launch/steps.py) installs a policy that re-constrains each
sliced block-param leaf to its TP-only sharding (data/pod axes removed),
which forces GSPMD to emit one all-gather per weight per scan iteration —
pipelined with compute by the scheduler, amortized over the microbatch
loop body.
"""
from __future__ import annotations

import contextlib
import contextvars
from typing import Any, Callable, Optional

_GATHER: contextvars.ContextVar[Optional[Callable]] = contextvars.ContextVar(
    "fsdp_gather", default=None
)


def gather_block(block_params: Any) -> Any:
    """Applied by model scan bodies to the per-iteration block params."""
    fn = _GATHER.get()
    return fn(block_params) if fn is not None else block_params


@contextlib.contextmanager
def gather_policy(fn: Callable):
    """Install a gather policy for the duration of a trace/lowering."""
    token = _GATHER.set(fn)
    try:
        yield
    finally:
        _GATHER.reset(token)


def make_tp_regather(mesh) -> Callable:
    """The standard policy: constrain every sliced block leaf back to its
    TP-only spec (derived from the leaf name — the same logical rules as
    param_pspecs, minus the FSDP data-axis sharding)."""
    import jax
    from jax.sharding import NamedSharding

    from repro.distributed import sharding as SH

    def gather(bp):
        def g(path, leaf):
            names = SH._path_names(path)
            spec = SH._leaf_spec(names, tuple(leaf.shape), mesh)
            return jax.lax.with_sharding_constraint(
                leaf, NamedSharding(mesh, spec)
            )

        return jax.tree_util.tree_map_with_path(g, bp)

    return gather

"""MeshPlan — the device-layout contract for the EBFT calibration walk.

The fused block-tuning loop (core/ebft.py) and the stacked dual-stream
walk (core/pruning/common.py) are written against this one object instead
of raw meshes: a plan says *which* mesh to run on and *how* each of the
three tensor families is laid out on it:

  * stacked calibration streams ``(n_mb, B, ...)`` — batch dim 1 sharded
    over the batch axes (``("data",)``, or ``("pod", "data")`` on a
    multi-pod mesh); the microbatch scan axis is never sharded.
  * block weights / masks / Adam moments — sharded over ``"model"`` by
    the logical-axis rules in :mod:`repro.distributed.sharding`
    (``param_pspecs``), the same layout the training cells use.
  * everything that does not divide its mesh axis falls back to
    replication *per leaf* — a plan never fails, it degrades, and
    :meth:`explain` reports exactly which leaves degraded (the
    ``repro.analysis`` sharding pass turns those into findings).

``MeshPlan.single()`` (or ``mesh_plan=None`` anywhere one is accepted)
is the bit-for-bit single-device path: no ``device_put``, no sharding
constraints, no collectives — the pre-mesh behavior exactly.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.distributed import sharding as SH


@dataclasses.dataclass(frozen=True)
class MeshPlan:
    """Device layout for a mesh-aware EBFT walk. ``mesh=None`` = single
    device (the legacy, bit-for-bit-unchanged path)."""

    mesh: Optional[Mesh] = None

    # -- constructors -------------------------------------------------------
    @staticmethod
    def single() -> "MeshPlan":
        return MeshPlan(None)

    @staticmethod
    def from_mesh(mesh: Mesh) -> "MeshPlan":
        return MeshPlan(mesh)

    # -- introspection ------------------------------------------------------
    @property
    def active(self) -> bool:
        """True when the plan actually shards (a mesh with >1 device).

        ``mesh.size`` (not ``mesh.devices``) so plans over AbstractMesh
        work too — the analysis sharding pass checks layouts device-free.
        """
        return self.mesh is not None and int(self.mesh.size) > 1

    @property
    def device_count(self) -> int:
        return int(self.mesh.size) if self.mesh is not None else 1

    @property
    def data_size(self) -> int:
        if self.mesh is None:
            return 1
        size = 1
        for a in SH.batch_axes(self.mesh):
            size *= SH.mesh_axis_size(self.mesh, a)
        return size

    @property
    def model_size(self) -> int:
        if self.mesh is None:
            return 1
        return SH.mesh_axis_size(self.mesh, SH.MODEL_AXIS)

    def describe(self) -> Dict[str, Any]:
        """Manifest-ready summary (goes into BENCH_*.json headers)."""
        if self.mesh is None:
            return {"devices": 1, "axes": {}, "active": False}
        return {
            "devices": self.device_count,
            "axes": {name: int(size) for name, size in self.mesh.shape.items()},
            "active": self.active,
        }

    # -- sharding rules -----------------------------------------------------
    def stacked_spec(self, leaf) -> P:
        """PartitionSpec for one stacked-stream leaf ``(n_mb, B, ...)``:
        shard the per-microbatch batch dim (dim 1) over the batch axes;
        replicate when it does not divide (the divisibility fallback the
        analysis pass reports)."""
        shape = tuple(leaf.shape)
        if self.mesh is None or len(shape) < 2:
            return P(*([None] * len(shape)))
        baxes = SH.batch_axes(self.mesh)
        bsize = self.data_size
        if bsize > 1 and shape[1] % bsize == 0 and shape[1] >= bsize:
            axis = baxes if len(baxes) > 1 else baxes[0]
            return P(None, axis, *([None] * (len(shape) - 2)))
        return P(*([None] * len(shape)))

    def stacked_shardings(self, tree: Any) -> Any:
        return jax.tree.map(
            lambda x: NamedSharding(self.mesh, self.stacked_spec(x)), tree
        )

    def block_pspecs(self, block_tree: Any) -> Any:
        """Model-axis layout for one block's weights/masks (and, by
        inheritance inside the fused dispatch, its Adam moments)."""
        return SH.param_pspecs(block_tree, self.mesh)

    def block_shardings(self, block_tree: Any) -> Any:
        return SH.named(self.block_pspecs(block_tree), self.mesh)

    # -- placement ----------------------------------------------------------
    def put_stacked(self, tree: Any) -> Any:
        """Data-shard a stacked-stream pytree (no-op for inactive plans)."""
        if not self.active:
            return tree
        return jax.device_put(tree, self.stacked_shardings(tree))

    def put_block(self, block_tree: Any) -> Any:
        """Model-shard one block's weights or masks (no-op when inactive)."""
        if not self.active:
            return block_tree
        return jax.device_put(block_tree, self.block_shardings(block_tree))

    # -- accounting ---------------------------------------------------------
    def sharded_bytes(self, tree: Any, specs: Optional[Any] = None) -> int:
        """Per-device bytes of ``tree`` under ``specs`` (default: the block
        layout) — the per-shard counterpart of obs.profile.live_bytes."""
        import numpy as np

        if not self.active:
            return int(sum(
                int(np.prod(np.shape(x)))
                * np.dtype(getattr(x, "dtype", np.float32)).itemsize
                for x in jax.tree.leaves(tree)
            ))
        specs = self.block_pspecs(tree) if specs is None else specs
        total = 0
        for leaf, spec in zip(
            jax.tree.leaves(tree),
            jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P)),
        ):
            n = int(np.prod(np.shape(leaf)))
            shards = 1
            for ax in spec:
                if ax is None:
                    continue
                for a in (ax if isinstance(ax, tuple) else (ax,)):
                    shards *= SH.mesh_axis_size(self.mesh, a)
            total += -(-n // shards) * np.dtype(
                getattr(leaf, "dtype", np.float32)).itemsize
        return total

    def allreduce_bytes(self, payload_bytes: int) -> int:
        """Total wire bytes of one ring all-reduce of ``payload_bytes``
        across the batch axes: 2·(d−1)·payload (reduce-scatter +
        all-gather, summed over devices). Zero when data_size == 1."""
        d = self.data_size
        return 0 if d <= 1 else 2 * (d - 1) * int(payload_bytes)

    def explain(self, tree: Any, stacked: bool = False) -> List[Tuple[str, P, bool]]:
        """(path, spec, sharded?) per leaf — ``sharded?`` False means the
        divisibility fallback replicated that leaf. Used by the analysis
        sharding pass and docs/DISTRIBUTED.md examples."""
        out: List[Tuple[str, P, bool]] = []
        if self.mesh is None:
            return out
        if stacked:
            flat = jax.tree_util.tree_flatten_with_path(tree)[0]
            for path, leaf in flat:
                spec = self.stacked_spec(leaf)
                name = "/".join(str(getattr(k, "key", k)) for k in path) or "leaf"
                out.append((name, spec, any(a is not None for a in spec)))
            return out
        specs = self.block_pspecs(tree)
        flat = jax.tree_util.tree_flatten_with_path(tree)[0]
        spec_leaves = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
        for (path, _leaf), spec in zip(flat, spec_leaves):
            name = "/".join(str(getattr(k, "key", k)) for k in path)
            out.append((name, spec, any(a is not None for a in spec)))
        return out

"""Activation-sharding constraints for distribution-agnostic model code.

Measured pathology (dry-run, every attention arch): the chunked-attention
scan carries (m, l, acc) are initialized with ``jnp.full``/``jnp.zeros``
— replicated constants. GSPMD infers the while-loop carry sharding from
that init, so the carry becomes batch-REPLICATED, which drags q/k/v and
the scores into batch-replicated form inside the loop: every device
computes attention for the WHOLE microbatch (16x redundant compute on the
256-chip mesh) and re-shards h at the loop boundary (activation-sized
all-gathers across data).

Model code stays mesh-agnostic: it tags tensors with a dims string
("bqhd", "bhq", ...) via ``constrain``; the launcher installs a policy
that maps 'b' -> the batch mesh axes and 'h' -> the model axis (when the
head count divides it). Without a policy the call is a no-op, so tests
and single-device runs are untouched.
"""
from __future__ import annotations

import contextlib
import contextvars
from typing import Callable, Optional

_POLICY: contextvars.ContextVar[Optional[Callable]] = contextvars.ContextVar(
    "act_sharding_policy", default=None
)


def constrain(x, dims: str):
    """dims: one char per axis of x — 'b' batch, 'h' heads, 'q'/'k' seq,
    'd' head_dim/feature, '.' unconstrained."""
    pol = _POLICY.get()
    return pol(x, dims) if pol is not None else x


@contextlib.contextmanager
def policy(fn: Callable):
    token = _POLICY.set(fn)
    try:
        yield
    finally:
        _POLICY.reset(token)


def make_mesh_policy(mesh, batch_axes=None) -> Callable:
    """'b' -> (pod, data) when divisible; 'h' -> model when divisible;
    everything else unconstrained. ``batch_axes`` overrides the batch
    mapping (e.g. pure-DP EBFT shards batch over data AND model)."""
    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.distributed import sharding as SH

    baxes = tuple(batch_axes) if batch_axes else SH.batch_axes(mesh)
    bsize = 1
    for a in baxes:
        bsize *= SH.mesh_axis_size(mesh, a)
    msize = SH.mesh_axis_size(mesh, SH.MODEL_AXIS)

    # if the batch mapping already consumes the model axis (pure-DP),
    # heads must stay unsharded
    model_free = SH.MODEL_AXIS not in baxes

    def pol(x, dims: str):
        spec = []
        for i, c in enumerate(dims[: x.ndim]):
            if c == "b" and x.shape[i] % bsize == 0 and x.shape[i] >= bsize:
                spec.append(baxes if len(baxes) > 1 else baxes[0])
            elif (c == "h" and model_free and x.shape[i] % msize == 0
                  and x.shape[i] >= msize):
                spec.append(SH.MODEL_AXIS)
            else:
                spec.append(None)
        spec += [None] * (x.ndim - len(spec))
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, P(*spec))
        )

    return pol

"""Logical-axis sharding rules -> NamedSharding (MaxText-style, best-effort).

One function, ``param_pspecs``, maps every parameter leaf to a
PartitionSpec by leaf name + shape, with divisibility checks against the
actual mesh (rules that don't divide fall back down a preference list, and
ultimately to replication — a 24-head Mamba on a 16-wide model axis simply
replicates heads and shards the head_dim instead).

Canonical tensor-parallel layout (one all-reduce per block, Megatron-style):
  * q/k/v projections column-parallel over heads  -> P(..., "model", None)
  * output projection  row-parallel over heads    -> P("model", None, ...)
  * MLP up/gate column-parallel over ff, down row-parallel over ff
  * MoE experts expert-parallel over E ("model" doubles as the EP axis)
  * embeddings / LM head sharded over the (128-padded) vocab
  * Mamba2 in/out projections split over heads (or head_dim as fallback)

Data parallelism: the batch axis of activations / inputs is sharded over
("pod", "data") when the mesh has a pod axis, else ("data",).

ZeRO-1: optimizer moments take the param spec and additionally shard one
still-unsharded axis over "data" when divisible (largest axis first) —
params stay replicated across data, moments are partitioned.
"""
from __future__ import annotations

from typing import Any, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.sparsity.sparse_params import _path_names

MODEL_AXIS = "model"
DATA_AXIS = "data"
POD_AXIS = "pod"


def mesh_axis_size(mesh: Mesh, name: str) -> int:
    # works for Mesh and AbstractMesh (rule tests use a 16x16 AbstractMesh
    # without needing 256 devices)
    return dict(mesh.shape).get(name, 1)


def batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    return (POD_AXIS, DATA_AXIS) if POD_AXIS in mesh.axis_names else (DATA_AXIS,)


def _fits(dim: int, size: int) -> bool:
    return dim % size == 0 and dim >= size


def _fits_padded(dim: int, size: int, max_waste: float = 1.0) -> bool:
    """GSPMD supports unevenly sharded dims (it pads). Allow when the pad
    waste (ceil(dim/size)*size/dim - 1) stays within ``max_waste`` — e.g.
    20 heads over a 16-wide axis pad to 32 (waste 0.6), which beats both
    replication (16x redundant attention compute) and head_dim sharding
    (scores contract over hd -> a per-chunk all-reduce of the full scores
    tensor, the pathology this rule exists to forbid)."""
    if dim <= 1:
        return False
    slots = -(-dim // size) * size
    return (slots - dim) / dim <= max_waste


# ---------------------------------------------------------------------------
# per-leaf rules: name -> list of (axis_index_from_end_of_logical_shape,
# axis, mode) candidates, tried in order. Leading stack axes (L, G, K, E...)
# are padded with None. ``mode``: "exact" requires divisibility; "pad"
# additionally allows GSPMD uneven sharding within the waste bound.
#
# Attention shards ONLY over heads: q/k/v head_dim sharding is forbidden
# (the scores einsum contracts hd, so hd sharding turns every attention
# chunk into a cross-device partial-sum). KV heads stay exact-only —
# under-divisible KV (GQA kv=8 on a 16-wide axis) replicates, which is the
# standard Megatron GQA fallback and costs only the small kv projections.
# Mamba B/C/dt/conv leaves replicate: they are O(d x N) small, and
# sharding the state dim N makes the SSD contraction cross-device.
# ---------------------------------------------------------------------------
_LOGICAL_RULES = {
    # name: (n_logical_dims, [(dim_idx, axis, mode), ...])
    "wq": (3, [(1, MODEL_AXIS, "pad")]),       # (d, H, hd)
    "wk": (3, [(1, MODEL_AXIS, "exact")]),     # (d, Hkv, hd)
    "wv": (3, [(1, MODEL_AXIS, "exact")]),
    "bq": (2, [(0, MODEL_AXIS, "pad")]),       # (H, hd)
    "bk": (2, [(0, MODEL_AXIS, "exact")]),
    "bv": (2, [(0, MODEL_AXIS, "exact")]),
    "wo": (3, [(0, MODEL_AXIS, "pad")]),       # (H, hd, d)
    "w_up": (2, [(1, MODEL_AXIS, "exact")]),   # (d, ff)
    "w_gate": (2, [(1, MODEL_AXIS, "exact")]),
    "w_down": (2, [(0, MODEL_AXIS, "exact")]), # (ff, d)
    "in_z": (3, [(1, MODEL_AXIS, "exact")]),   # (d, H, P)
    "in_x": (3, [(1, MODEL_AXIS, "exact")]),
    "in_dt": (2, [(1, MODEL_AXIS, "exact")]),  # (d, H)
    "out": (3, [(0, MODEL_AXIS, "exact")]),    # (H, P, d)
    "gnorm_w": (1, [(0, MODEL_AXIS, "exact")]),# (H*P,) follows heads
    "tok": (2, [(0, MODEL_AXIS, "exact")]),    # (V, d)
    "head_w": (2, [(1, MODEL_AXIS, "exact")]), # (d, V)
}
# expert-batched leaves: shard E over model (EP) first; ff fallback
_EXPERT_LEAF_DIMS = {"w_up": 3, "w_gate": 3, "w_down": 3}


def _leaf_spec(names: Tuple[str, ...], shape: Tuple[int, ...], mesh: Mesh) -> P:
    name = names[-1]
    msize = mesh_axis_size(mesh, MODEL_AXIS)
    ndim = len(shape)

    key = name
    if name == "w" and "head" in names:
        key = "head_w"
    if name == "w" and "gnorm" in names:
        key = "gnorm_w"
    if name == "w" and "router" in names:
        return P(*([None] * ndim))  # routers replicate

    # MoE expert tensors: (..., E, d, ff) — expert-parallel over E
    if key in _EXPERT_LEAF_DIMS and "experts" in names:
        e_idx = ndim - 3
        if _fits(shape[e_idx], msize):
            spec = [None] * ndim
            spec[e_idx] = MODEL_AXIS
            return P(*spec)
        # fall through to ff sharding below

    if key not in _LOGICAL_RULES:
        return P(*([None] * ndim))

    n_logical, candidates = _LOGICAL_RULES[key]
    lead = ndim - n_logical  # stacked (L / G,K / E) axes
    if lead < 0:
        return P(*([None] * ndim))
    for dim_idx, axis, mode in candidates:
        d = lead + dim_idx
        size = mesh_axis_size(mesh, axis)
        # NOTE: pjit requires input dims divisible by their mesh axis, so
        # "pad" mode cannot be expressed via shardings alone. Non-divisible
        # head counts are handled by zero-padded head expansion at the
        # parameter level (launch/steps.py pad_q_heads) which IS exact.
        ok = _fits(shape[d], size)
        if ok:
            spec = [None] * ndim
            spec[d] = axis
            return P(*spec)
    return P(*([None] * ndim))


def param_pspecs(params_tree: Any, mesh: Mesh, fsdp: bool = False) -> Any:
    """Pytree of PartitionSpec matching ``params_tree`` (arrays or
    ShapeDtypeStructs).

    ``fsdp=True`` additionally shards each leaf's largest still-free,
    divisible axis over the batch axes (pod×data) — fully-sharded params
    for the configs whose TP-sharded weights alone exceed per-chip HBM
    (qwen1.5-110b, kimi-k2). XLA inserts the per-layer all-gathers
    (scan-over-layers keeps them pipelined with compute).
    """
    baxes = batch_axes(mesh)
    bsize = 1
    for a in baxes:
        bsize *= mesh_axis_size(mesh, a)

    def g(path, leaf):
        shape = tuple(leaf.shape)
        spec = list(_leaf_spec(_path_names(path), shape, mesh))
        if fsdp and len(shape) >= 2:
            order = sorted(range(len(shape)), key=lambda i: -shape[i])
            for i in order:
                if spec[i] is None and _fits(shape[i], bsize):
                    spec[i] = baxes if len(baxes) > 1 else baxes[0]
                    break
        return P(*spec)

    return jax.tree_util.tree_map_with_path(g, params_tree)


def opt_pspecs(opt_shapes: Any, param_specs_by_path: Any, mesh: Mesh) -> Any:
    """ZeRO-1 moment sharding: param spec + shard one free axis over "data".

    ``opt_shapes`` is the eval_shape tree of the optimizer state; moment
    leaves mirror param shapes. Leaves without a param analogue (step
    counters) replicate.
    """
    dsize = mesh_axis_size(mesh, DATA_AXIS)

    def g(path, leaf):
        names = _path_names(path)
        shape = tuple(leaf.shape)
        if len(shape) == 0:
            return P()
        # moment leaves live under m/v/mu/<param path...>
        base = _leaf_spec(tuple(n for n in names if n not in ("m", "v", "mu")), shape, mesh)
        spec = list(base) + [None] * (len(shape) - len(base))
        # ZeRO-1: add "data" on the largest unsharded, divisible axis
        order = sorted(range(len(shape)), key=lambda i: -shape[i])
        for i in order:
            if spec[i] is None and _fits(shape[i], dsize):
                spec[i] = DATA_AXIS
                break
        return P(*spec)

    return jax.tree_util.tree_map_with_path(g, opt_shapes)


# ---------------------------------------------------------------------------
# activations / inputs / serve state
# ---------------------------------------------------------------------------
def batch_pspecs(batch_tree: Any, mesh: Mesh) -> Any:
    """Input batches: dim 0 = global batch over (pod, data); rest replicated.
    Batch dims that don't divide fall back to replication (long_500k B=1)."""
    baxes = batch_axes(mesh)
    bsize = 1
    for a in baxes:
        bsize *= mesh_axis_size(mesh, a)

    def g(leaf):
        shape = tuple(leaf.shape)
        if not shape:
            return P()
        if _fits(shape[0], bsize):
            return P(baxes, *([None] * (len(shape) - 1)))
        # try data-only (pod replicated)
        if len(baxes) > 1 and _fits(shape[0], mesh_axis_size(mesh, DATA_AXIS)):
            return P(DATA_AXIS, *([None] * (len(shape) - 1)))
        return P(*([None] * len(shape)))

    return jax.tree.map(g, batch_tree)


def cache_pspecs(cache_tree: Any, mesh: Mesh) -> Any:
    """Serve-state (KV cache / SSM state) sharding.

    Leaves look like (L, B, S, Hkv, hd), (L, B, K, ch), (G, K, B, ...) etc.
    Heuristic: shard the *batch* dim over data (first dim of size == serve
    batch — detected as the first dim after any leading stack dims that
    divides the data axis), and the heads/channel dim over model when
    divisible. Scalars ("len") replicate.
    """
    dsize = mesh_axis_size(mesh, DATA_AXIS)
    msize = mesh_axis_size(mesh, MODEL_AXIS)

    def g(leaf):
        shape = tuple(leaf.shape)
        if len(shape) <= 1:
            return P(*([None] * len(shape)))
        spec: list = [None] * len(shape)
        # batch dim: first dim (scanning from axis 0) divisible by data size,
        # skipping obvious layer-stack leading axes by preferring axis 1+ for
        # rank>=3 leaves.
        start = 1 if len(shape) >= 3 else 0
        for i in range(start, len(shape)):
            if _fits(shape[i], dsize):
                spec[i] = DATA_AXIS
                break
        # heads / channels dim: prefer dim -2 (heads / state), then -1
        # (head_dim / channels). Never shard the sequence axis of a cache.
        for i in (len(shape) - 2, len(shape) - 1):
            if i >= 0 and spec[i] is None and _fits(shape[i], msize):
                spec[i] = MODEL_AXIS
                break
        return P(*spec)

    return jax.tree.map(g, cache_tree)


def named(tree_of_specs: Any, mesh: Mesh) -> Any:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        tree_of_specs,
        is_leaf=lambda x: isinstance(x, P),
    )

"""Zero-padded attention-head expansion (exact-semantics TP enabler).

A 40-head model cannot head-shard on a 16-wide model axis; the baseline
fallback (replicate attention) costs every model shard the FULL attention
pipeline (measured 16x its fair share of compute and HBM traffic on
qwen2.5-32b). Padding q heads to the next multiple restores head TP and
is EXACTLY the same function:

  * a padded q head has zero wq rows -> q = 0 -> uniform softmax over its
    kv group -> some context vector c,
  * but its wo rows are zero -> contribution wo_pad @ c = 0.

For GQA the pad is inserted PER KV GROUP (the q->kv mapping of real heads
must not shift), so weights are reshaped (d, KV, G, hd) and the G axis is
padded. For MHA, q and kv pad together (padded kv heads only serve padded
q heads). ``head_pad_mask`` marks the padded slots so training can freeze
them (their gradient is NOT zero — the uniform-softmax context flows into
wo_pad's grad — so the mask must be applied each update).

``launch/steps.py:padded_heads`` computes the padded counts; this module
transforms real weight pytrees (tests pin forward-exactness at tiny
scale).
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.sparsity.sparse_params import _path_names

Params = Any

_Q_LEAVES = {"wq": -2, "bq": -2, "wo": -3}   # head-axis index from the end
_KV_LEAVES = {"wk": -2, "bk": -2, "wv": -2, "bv": -2}


def _pad_axis(leaf: jax.Array, axis: int, new: int) -> jax.Array:
    axis = axis % leaf.ndim
    pad = [(0, 0)] * leaf.ndim
    pad[axis] = (0, new - leaf.shape[axis])
    return jnp.pad(leaf, pad)


def _pad_grouped(leaf: jax.Array, axis: int, kv: int, g_old: int, g_new: int) -> jax.Array:
    """(... H=kv*g_old ...) -> (... kv*g_new ...) padding inside each group."""
    axis = axis % leaf.ndim
    shape = leaf.shape
    grouped = leaf.reshape(*shape[:axis], kv, g_old, *shape[axis + 1:])
    pad = [(0, 0)] * grouped.ndim
    pad[axis + 1] = (0, g_new - g_old)
    grouped = jnp.pad(grouped, pad)
    return grouped.reshape(*shape[:axis], kv * g_new, *shape[axis + 1:])


def pad_attention_params(
    params: Params, cfg_old: ModelConfig, cfg_new: ModelConfig
) -> Params:
    """Expand every attention leaf from (H, KV) to the padded (H', KV')."""
    h0, kv0 = cfg_old.num_heads, cfg_old.num_kv_heads
    h1, kv1 = cfg_new.num_heads, cfg_new.num_kv_heads
    if (h0, kv0) == (h1, kv1):
        return params
    mha = kv0 == h0

    def g(path, leaf):
        name = _path_names(path)[-1]
        if name in _Q_LEAVES and leaf.shape[_Q_LEAVES[name] % leaf.ndim] == h0:
            ax = _Q_LEAVES[name]
            if mha:
                return _pad_axis(leaf, ax, h1)
            return _pad_grouped(leaf, ax, kv0, h0 // kv0, h1 // kv1)
        if mha and name in _KV_LEAVES and leaf.shape[_KV_LEAVES[name] % leaf.ndim] == kv0:
            return _pad_axis(leaf, _KV_LEAVES[name], kv1)
        return leaf

    return jax.tree_util.tree_map_with_path(g, params)


def head_pad_mask(
    params_padded: Params, cfg_old: ModelConfig, cfg_new: ModelConfig
) -> Params:
    """1.0 on real slots, 0.0 on padded head slots (multiply into grads or
    updates each step to keep the pads frozen at zero)."""
    h0, kv0 = cfg_old.num_heads, cfg_old.num_kv_heads
    h1, kv1 = cfg_new.num_heads, cfg_new.num_kv_heads
    mha = kv0 == h0

    def mask_for(leaf, ax, n_old_groups, group_old, group_new, kv):
        ax = ax % leaf.ndim
        m = jnp.ones(leaf.shape, jnp.float32)
        if mha:
            idx = [slice(None)] * leaf.ndim
            idx[ax] = slice(h0, None)
            return m.at[tuple(idx)].set(0.0)
        shape = leaf.shape
        gm = m.reshape(*shape[:ax], kv, group_new, *shape[ax + 1:])
        idx = [slice(None)] * gm.ndim
        idx[ax + 1] = slice(group_old, None)
        gm = gm.at[tuple(idx)].set(0.0)
        return gm.reshape(shape)

    def g(path, leaf):
        name = _path_names(path)[-1]
        if name in _Q_LEAVES and leaf.shape[_Q_LEAVES[name] % leaf.ndim] == h1:
            return mask_for(leaf, _Q_LEAVES[name], None,
                            h0 // kv0, h1 // kv1, kv0)
        if mha and name in _KV_LEAVES and leaf.shape[_KV_LEAVES[name] % leaf.ndim] == kv1:
            return mask_for(leaf, _KV_LEAVES[name], None, h0 // kv0,
                            h1 // kv1, kv0)
        return jnp.ones((), jnp.float32)

    return jax.tree_util.tree_map_with_path(g, params_padded)

"""Synthetic token data pipeline.

The container has no C4 / Wikitext2 on disk, so we build a deterministic
synthetic corpus with enough statistical structure that language-modelling
loss is meaningful and pruning hurts it (DESIGN.md §7):

* a Zipf-distributed unigram backbone (natural-language-like frequencies),
* a first-order Markov kernel so contexts carry information (models that
  capture bigram structure beat the unigram entropy floor),
* deterministic "template" n-grams injected at random offsets, giving
  mid-range structure that block fine-tuning can recover.

Two consumers:
  - ``corpus_iterator``: packed (B, S) batches for pre-training / eval.
  - ``calibration_set``: the paper's D_c — N segments of ``seq_len`` tokens
    (paper: 256 x 1024 from C4) sampled with a fixed seed.

Everything is pure-numpy on the host (the real system would stream from a
tokenised dataset service); device placement happens in the train loop.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class CorpusConfig:
    vocab_size: int
    zipf_a: float = 1.2          # Zipf exponent for the unigram backbone
    markov_rank: int = 16        # low-rank bigram kernel size
    markov_weight: float = 0.55  # interpolation: P = w*bigram + (1-w)*unigram
    n_templates: int = 64        # injected deterministic n-grams
    template_len: int = 8
    template_rate: float = 0.05  # fraction of positions starting a template
    seed: int = 0


class SyntheticCorpus:
    """Deterministic synthetic corpus sampler (Zipf + low-rank Markov)."""

    def __init__(self, cfg: CorpusConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        V = cfg.vocab_size

        # Zipf unigram distribution over the vocab.
        ranks = np.arange(1, V + 1, dtype=np.float64)
        uni = ranks ** (-cfg.zipf_a)
        self.unigram = uni / uni.sum()

        # Low-rank Markov structure: token -> cluster -> next-token tilt.
        R = cfg.markov_rank
        self.tok2cluster = rng.integers(0, R, size=V)
        # per-cluster tilt: a random permutation bias over a slice of the vocab
        tilt = rng.dirichlet(np.full(V, 0.05), size=R)
        self.cluster_next = 0.5 * tilt + 0.5 * self.unigram[None, :]
        self.cluster_next /= self.cluster_next.sum(-1, keepdims=True)

        # deterministic templates (frequent n-grams)
        self.templates = rng.integers(
            0, max(2, V // 8), size=(cfg.n_templates, cfg.template_len)
        )

    def sample(self, rng: np.random.Generator, length: int) -> np.ndarray:
        cfg = self.cfg
        out = np.empty(length, dtype=np.int32)
        # vectorised-ish: draw in chunks, falling back to the Markov kernel
        prev = int(rng.choice(cfg.vocab_size, p=self.unigram))
        i = 0
        while i < length:
            if rng.random() < cfg.template_rate:
                t = self.templates[rng.integers(cfg.n_templates)]
                n = min(len(t), length - i)
                out[i : i + n] = t[:n]
                i += n
                prev = int(out[i - 1])
                continue
            c = self.tok2cluster[prev]
            p = (
                cfg.markov_weight * self.cluster_next[c]
                + (1 - cfg.markov_weight) * self.unigram
            )
            prev = int(rng.choice(cfg.vocab_size, p=p))
            out[i] = prev
            i += 1
        return out


def corpus_iterator(
    corpus: SyntheticCorpus,
    batch: int,
    seq_len: int,
    seed: int = 1234,
) -> Iterator[np.ndarray]:
    """Yields packed (batch, seq_len) int32 batches forever."""
    rng = np.random.default_rng(seed)
    while True:
        yield np.stack([corpus.sample(rng, seq_len) for _ in range(batch)])


def calibration_set(
    corpus: SyntheticCorpus, n_samples: int, seq_len: int, seed: int = 42
) -> np.ndarray:
    """The paper's D_c: ``n_samples`` segments of ``seq_len`` tokens.

    Paper setting: 256 segments x 1024 tokens from C4. Fixed seed so every
    pruning/fine-tuning method sees the identical calibration set.
    """
    rng = np.random.default_rng(seed)
    return np.stack([corpus.sample(rng, seq_len) for _ in range(n_samples)])


def eval_set(
    corpus: SyntheticCorpus, n_samples: int, seq_len: int, seed: int = 7777
) -> np.ndarray:
    """Held-out evaluation segments (our Wikitext2 stand-in)."""
    rng = np.random.default_rng(seed)
    return np.stack([corpus.sample(rng, seq_len) for _ in range(n_samples)])


def cloze_task(
    corpus: SyntheticCorpus, n_samples: int, seq_len: int, seed: int = 555
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Synthetic zero-shot-style cloze task (our Tab.3 stand-in).

    Each sample ends with a template prefix; the task is to rank the true
    template continuation above a corrupted one. Returns
    (contexts (N, seq_len), true_next (N,), distractor_next (N,)).
    """
    cfg = corpus.cfg
    rng = np.random.default_rng(seed)
    ctx = np.empty((n_samples, seq_len), np.int32)
    true_next = np.empty((n_samples,), np.int32)
    distract = np.empty((n_samples,), np.int32)
    for i in range(n_samples):
        body = corpus.sample(rng, seq_len)
        t = corpus.templates[rng.integers(cfg.n_templates)]
        k = len(t) - 1
        body[-k:] = t[:k]
        ctx[i] = body
        true_next[i] = t[k]
        d = int(rng.integers(cfg.vocab_size))
        while d == t[k]:
            d = int(rng.integers(cfg.vocab_size))
        distract[i] = d
    return ctx, true_next, distract

"""Tiny ssm config for tests/benches (alias of mamba2_130m SMOKE)."""
from repro.configs.base import ModelConfig

from repro.configs.mamba2_130m import SMOKE as CONFIG

SMOKE = CONFIG

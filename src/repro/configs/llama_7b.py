"""LlamaV1/V2-7B — the paper's own evaluation model (EBFT Tables 1-6)."""
from repro.configs.base import ModelConfig


CONFIG = ModelConfig(
    name="llama-7b", family="dense",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=32,
    d_ff=11008, vocab_size=32000,
    mlp_act="swiglu", norm="rmsnorm",
)

SMOKE = CONFIG.replace(
    name="llama-7b-smoke", num_layers=2, d_model=64, num_heads=4,
    num_kv_heads=4, d_ff=128, vocab_size=512,
)

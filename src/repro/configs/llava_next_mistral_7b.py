"""LLaVA-NeXT (Mistral-7B backbone) [hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified] — anyres vision frontend is a stub supplying precomputed patch embeddings (up to 5 tiles x 576 patches)."""
from repro.configs.base import ModelConfig


CONFIG = ModelConfig(
    name="llava-next-mistral-7b", family="vlm",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8,
    d_ff=14336, vocab_size=32000,
    mlp_act="swiglu", norm="rmsnorm", rope_theta=1e6,
    frontend="vision", frontend_len=2880,
)

SMOKE = CONFIG.replace(
    name="llava-next-smoke", num_layers=2, d_model=64, num_heads=4,
    num_kv_heads=2, d_ff=128, vocab_size=512, frontend_len=8,
)

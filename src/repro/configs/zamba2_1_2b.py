"""Zamba2-1.2B [arXiv:2411.15242; hf] — Mamba2 backbone + one shared attention block every 6 layers."""
from repro.configs.base import ModelConfig


CONFIG = ModelConfig(
    name="zamba2-1.2b", family="hybrid",
    num_layers=38, d_model=2048, num_heads=32, num_kv_heads=32,
    d_ff=8192, vocab_size=32000,
    mlp_act="swiglu", norm="rmsnorm",
    ssm_state=64, ssm_head_dim=64, ssm_expand=2, ssm_conv=4, ssm_chunk=256,
    hybrid_attn_every=6,
)

SMOKE = CONFIG.replace(
    name="zamba2-1.2b-smoke", num_layers=5, d_model=64, num_heads=4,
    num_kv_heads=4, d_ff=128, vocab_size=512,
    ssm_state=16, ssm_head_dim=16, ssm_chunk=32, hybrid_attn_every=2,
)

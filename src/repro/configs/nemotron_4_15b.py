"""Nemotron-4-15B [arXiv:2402.16819; unverified] — dense, GQA kv=8, squared-ReLU MLP."""
from repro.configs.base import ModelConfig


CONFIG = ModelConfig(
    name="nemotron-4-15b", family="dense",
    num_layers=32, d_model=6144, num_heads=48, num_kv_heads=8,
    d_ff=24576, vocab_size=256000,
    qkv_bias=False, mlp_act="sq_relu", norm="layernorm", rope_theta=10000.0,
)

SMOKE = CONFIG.replace(
    name="nemotron-4-15b-smoke", num_layers=2, d_model=64, num_heads=4,
    num_kv_heads=2, d_ff=128, vocab_size=512,
)

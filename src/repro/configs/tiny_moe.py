"""Tiny moe config for tests/benches (alias of deepseek_moe_16b SMOKE)."""
from repro.configs.base import ModelConfig

from repro.configs.deepseek_moe_16b import SMOKE as CONFIG

SMOKE = CONFIG

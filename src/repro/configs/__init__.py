"""Config registry: ``get_config(name)`` / ``list_configs()``.

One module per assigned architecture plus the paper's own Llama-7B and tiny
test variants. Each module exposes ``CONFIG`` (exact assigned numbers) and
``SMOKE`` (same family, reduced) ModelConfigs.
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import (
    ALL_SHAPES,
    DECODE_32K,
    LONG_500K,
    PREFILL_32K,
    TRAIN_4K,
    ModelConfig,
    ShapeConfig,
)

ARCH_IDS = [
    "qwen1_5_4b",
    "nemotron_4_15b",
    "qwen2_5_32b",
    "qwen1_5_110b",
    "zamba2_1_2b",
    "kimi_k2_1t_a32b",
    "deepseek_moe_16b",
    "seamless_m4t_medium",
    "mamba2_130m",
    "llava_next_mistral_7b",
]
EXTRA_IDS = ["llama_7b", "tiny_dense", "tiny_moe", "tiny_ssm", "tiny_hybrid",
             "tiny_encdec", "tiny_vlm"]

_ALIAS = {i.replace("_", "-"): i for i in ARCH_IDS + EXTRA_IDS}


def get_config(name: str, smoke: bool = False) -> ModelConfig:
    name = _ALIAS.get(name, name)
    mod = importlib.import_module(f"repro.configs.{name}")
    if smoke:
        return getattr(mod, "SMOKE", mod.CONFIG)
    return mod.CONFIG


def list_configs() -> List[str]:
    return list(ARCH_IDS)


def get_shape(name: str) -> ShapeConfig:
    for s in ALL_SHAPES:
        if s.name == name:
            return s
    raise KeyError(name)

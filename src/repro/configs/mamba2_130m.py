"""Mamba2-130M [arXiv:2405.21060; unverified] — attention-free SSD (state-space duality)."""
from repro.configs.base import ModelConfig


CONFIG = ModelConfig(
    name="mamba2-130m", family="ssm",
    num_layers=24, d_model=768, num_heads=0, num_kv_heads=0,
    d_ff=0, vocab_size=50280,
    norm="rmsnorm",
    ssm_state=128, ssm_head_dim=64, ssm_expand=2, ssm_conv=4, ssm_chunk=256,
)

SMOKE = CONFIG.replace(
    name="mamba2-smoke", num_layers=2, d_model=64, vocab_size=512,
    ssm_state=16, ssm_head_dim=16, ssm_chunk=32,
)

"""Qwen1.5-110B [hf:Qwen/Qwen1.5-0.5B family; hf] — dense, GQA kv=8, QKV bias."""
from repro.configs.base import ModelConfig


CONFIG = ModelConfig(
    name="qwen1.5-110b", family="dense",
    num_layers=80, d_model=8192, num_heads=64, num_kv_heads=8,
    d_ff=49152, vocab_size=152064,
    qkv_bias=True, mlp_act="swiglu", norm="rmsnorm", rope_theta=1e6,
)

SMOKE = CONFIG.replace(
    name="qwen1.5-110b-smoke", num_layers=2, d_model=64, num_heads=4,
    num_kv_heads=2, d_ff=128, vocab_size=512,
)

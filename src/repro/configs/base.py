"""Model / run configuration dataclasses.

Every assigned architecture is expressed as a ``ModelConfig``; shapes
(train/prefill/decode/long-context) are ``ShapeConfig``s. Configs are frozen
dataclasses so they can be closed over by jit'd functions safely.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class ShapeConfig:
    """One (input-shape) cell from the assignment."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


# The four assigned shapes (identical across LM-family archs).
TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524288, 1, "decode")
ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // num_heads

    # ---- attention / block details ----
    qkv_bias: bool = False
    mlp_act: str = "swiglu"  # swiglu | sq_relu | gelu
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    attn_impl: str = "dot"  # dot | chunked | flash
    attn_chunk: int = 1024  # kv-chunk for chunked/flash attention
    attn_q_chunk: int = 0   # >0: block queries too (32k prefill memory)

    # ---- MoE ----
    moe_num_experts: int = 0
    moe_top_k: int = 0
    moe_num_shared: int = 0
    moe_d_ff: int = 0  # per-expert hidden dim
    moe_first_dense: int = 0  # leading dense layers (DeepSeek/Kimi style)
    moe_capacity_factor: float = 1.25
    # dispatch groups: tokens are routed within G independent groups with
    # per-group capacity. Set G = data-parallel shards at scale so the
    # (G, E, C, d) dispatch buffer shards as (data, model/EP, ., .) with
    # *local* capacity — the global-capacity buffer would be O(total
    # tokens) per device. G=1 reproduces plain global dispatch.
    moe_dispatch_groups: int = 1

    # ---- SSM (Mamba2 / SSD) ----
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_chunk: int = 256

    # ---- hybrid (Zamba2) ----
    hybrid_attn_every: int = 0  # apply the shared attention block every k layers

    # ---- enc-dec (Seamless-M4T) ----
    enc_layers: int = 0  # when >0, num_layers is the decoder depth

    # ---- modality frontends (stubs per assignment) ----
    frontend: str = "none"  # none | vision | audio
    frontend_len: int = 0  # patch / frame count supplied by input_specs()

    # ---- numerics ----
    dtype: str = "float32"  # activation/compute dtype
    param_dtype: str = "float32"
    vocab_pad_multiple: int = 128
    remat: str = "none"  # none | block
    # scan group size for hybrid models
    max_position: int = 524288

    # ------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // max(self.num_heads, 1))

    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_multiple
        return ((self.vocab_size + m - 1) // m) * m

    @property
    def ssm_d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_num_heads(self) -> int:
        return self.ssm_d_inner // self.ssm_head_dim

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic families run long_500k; full-attention archs skip it."""
        return self.family in ("ssm", "hybrid")

    def shapes(self) -> Tuple[ShapeConfig, ...]:
        """The assigned shape cells applicable to this arch."""
        out = []
        for s in ALL_SHAPES:
            if s.name == "long_500k" and not self.supports_long_context:
                continue
            out.append(s)
        return tuple(out)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # Parameter count (for MODEL_FLOPS = 6*N*D roofline accounting).
    def param_count(self, active_only: bool = False) -> int:
        d, hd = self.d_model, self.resolved_head_dim
        h, kv = self.num_heads, self.num_kv_heads
        V = self.padded_vocab

        def attn_params() -> int:
            p = d * hd * (h + 2 * kv) + h * hd * d
            if self.qkv_bias:
                p += hd * (h + 2 * kv)
            return p

        def dense_mlp(ff: int) -> int:
            mult = 3 if self.mlp_act == "swiglu" else 2
            return mult * d * ff

        if self.family == "ssm":
            di, N, H = self.ssm_d_inner, self.ssm_state, self.ssm_num_heads
            per_layer = (
                d * (2 * di + 2 * N + H)  # in_proj (z, x, B, C, dt)
                + self.ssm_conv * (di + 2 * N)  # conv
                + di * d  # out_proj
                + 3 * H  # A_log, D, dt_bias
                + di  # gated norm
            )
            body = self.num_layers * (per_layer + d)
        elif self.family == "hybrid":
            di, N, H = self.ssm_d_inner, self.ssm_state, self.ssm_num_heads
            mamba = (
                d * (2 * di + 2 * N + H)
                + self.ssm_conv * (di + 2 * N)
                + di * d
                + 3 * H
                + di
                + d
            )
            shared = attn_params() + dense_mlp(self.d_ff) + 2 * d
            body = self.num_layers * mamba + shared
        elif self.family == "moe":
            n_moe = self.num_layers - self.moe_first_dense
            k = self.moe_top_k if active_only else self.moe_num_experts
            per_moe = (
                attn_params()
                + d * self.moe_num_experts  # router (always active)
                + (k + self.moe_num_shared) * dense_mlp(self.moe_d_ff) // 1
                + 2 * d
            )
            per_dense = attn_params() + dense_mlp(self.d_ff) + 2 * d
            body = n_moe * per_moe + self.moe_first_dense * per_dense
        elif self.family == "encdec":
            enc = self.enc_layers * (attn_params() + dense_mlp(self.d_ff) + 2 * d)
            dec = self.num_layers * (
                2 * attn_params() + dense_mlp(self.d_ff) + 3 * d
            )
            body = enc + dec
        else:  # dense | vlm
            body = self.num_layers * (attn_params() + dense_mlp(self.d_ff) + 2 * d)

        embed = V * d * (1 if self.tie_embeddings else 2)
        return body + embed + d  # + final norm

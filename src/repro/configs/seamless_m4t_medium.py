"""Seamless-M4T-medium [arXiv:2308.11596; hf] — enc-dec text backbone; audio frontend is a stub supplying precomputed frame embeddings (assignment)."""
from repro.configs.base import ModelConfig


CONFIG = ModelConfig(
    name="seamless-m4t-medium", family="encdec",
    num_layers=12, enc_layers=12, d_model=1024, num_heads=16, num_kv_heads=16,
    d_ff=4096, vocab_size=256206,
    mlp_act="gelu", norm="layernorm",
    frontend="audio",
)

SMOKE = CONFIG.replace(
    name="seamless-m4t-smoke", num_layers=2, enc_layers=2, d_model=64,
    num_heads=4, num_kv_heads=4, d_ff=128, vocab_size=512,
)

"""Tiny vlm config for tests/benches (alias of llava_next_mistral_7b SMOKE)."""
from repro.configs.base import ModelConfig

from repro.configs.llava_next_mistral_7b import SMOKE as CONFIG

SMOKE = CONFIG

"""Tiny dense config for tests/benches (alias of llama_7b SMOKE)."""
from repro.configs.base import ModelConfig

from repro.configs.llama_7b import SMOKE as CONFIG

SMOKE = CONFIG

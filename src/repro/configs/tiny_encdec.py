"""Tiny encdec config for tests/benches (alias of seamless_m4t_medium SMOKE)."""
from repro.configs.base import ModelConfig

from repro.configs.seamless_m4t_medium import SMOKE as CONFIG

SMOKE = CONFIG

"""Kimi-K2 1T-A32B [arXiv:2501.kimi2; unverified] — trillion-param MoE, 384 routed experts top-8 + 1 shared, first layer dense. head_dim=128 per the released config (64 heads x 128 > d_model, DeepSeek-V3 convention); dense-layer d_ff=18432 = moe_d_ff*(top_k+shared) matches the released dense FFN."""
from repro.configs.base import ModelConfig


CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b", family="moe",
    num_layers=61, d_model=7168, num_heads=64, num_kv_heads=8, head_dim=128,
    d_ff=18432, vocab_size=163840,
    mlp_act="swiglu", norm="rmsnorm",
    moe_num_experts=384, moe_top_k=8, moe_num_shared=1, moe_d_ff=2048,
    moe_first_dense=1,
)

SMOKE = CONFIG.replace(
    name="kimi-k2-smoke", num_layers=3, d_model=64, num_heads=4,
    num_kv_heads=2, head_dim=16, d_ff=256, vocab_size=512,
    moe_num_experts=8, moe_top_k=2, moe_num_shared=1, moe_d_ff=32,
    moe_first_dense=1,
)

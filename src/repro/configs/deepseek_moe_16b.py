"""DeepSeek-MoE-16B [arXiv:2401.06066; hf] — fine-grained MoE: 64 routed top-6 + 2 shared experts, first layer dense (released dense d_ff=10944)."""
from repro.configs.base import ModelConfig


CONFIG = ModelConfig(
    name="deepseek-moe-16b", family="moe",
    num_layers=28, d_model=2048, num_heads=16, num_kv_heads=16,
    d_ff=10944, vocab_size=102400,
    mlp_act="swiglu", norm="rmsnorm",
    moe_num_experts=64, moe_top_k=6, moe_num_shared=2, moe_d_ff=1408,
    moe_first_dense=1,
)

SMOKE = CONFIG.replace(
    name="deepseek-moe-smoke", num_layers=3, d_model=64, num_heads=4,
    num_kv_heads=4, d_ff=256, vocab_size=512,
    moe_num_experts=8, moe_top_k=2, moe_num_shared=2, moe_d_ff=32,
    moe_first_dense=1,
)

"""Tiny hybrid config for tests/benches (alias of zamba2_1_2b SMOKE)."""
from repro.configs.base import ModelConfig

from repro.configs.zamba2_1_2b import SMOKE as CONFIG

SMOKE = CONFIG

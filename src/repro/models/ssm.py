"""Mamba2 (SSD — state-space duality) blocks and the attn-free LM.

The SSD chunked algorithm (Dao & Gu 2024) adapted to TPU idioms:
  * intra-chunk term: a (Q × Q) masked-decay "attention" per chunk — dense
    MXU-friendly einsums;
  * inter-chunk term: a `jax.lax.scan` carrying the (B, H, N, P) state.
Sequence cost is O(S·Q) instead of O(S²) — this is the sub-quadratic path
that makes `long_500k` runnable.

Recurrence (per head h, state size N, head dim P):
    h_t = exp(dt_t · A) · h_{t-1} + dt_t · B_t ⊗ x_t
    y_t = C_t · h_t + D · x_t
with B_t, C_t shared across heads (ngroups = 1, the Mamba2 default).
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed import fsdp
from repro.models import layers as L

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------
def init_mamba_block(key, cfg: ModelConfig, dtype) -> Params:
    d, N, K = cfg.d_model, cfg.ssm_state, cfg.ssm_conv
    H, P = cfg.ssm_num_heads, cfg.ssm_head_dim
    conv_ch = H * P + 2 * N
    ks = jax.random.split(key, 7)
    s = 1.0 / math.sqrt(d)
    return {
        "ln": L.init_norm(d, cfg.norm, dtype),
        "in_z": (jax.random.normal(ks[0], (d, H, P)) * s).astype(dtype),
        "in_x": (jax.random.normal(ks[1], (d, H, P)) * s).astype(dtype),
        "in_B": (jax.random.normal(ks[2], (d, N)) * s).astype(dtype),
        "in_C": (jax.random.normal(ks[3], (d, N)) * s).astype(dtype),
        "in_dt": (jax.random.normal(ks[4], (d, H)) * s).astype(dtype),
        "conv_w": (jax.random.normal(ks[5], (K, conv_ch)) * (1.0 / math.sqrt(K))).astype(dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "A_log": jnp.log(jnp.arange(1, H + 1, dtype=jnp.float32)),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.full((H,), -2.0, jnp.float32),  # softplus(-2) ~ 0.13
        "gnorm": {"w": jnp.ones((H * P,), dtype)},
        "out": (jax.random.normal(ks[6], (H, P, d)) * (1.0 / math.sqrt(H * P))).astype(dtype),
    }


def init(rng, cfg: ModelConfig) -> Params:
    dtype = jnp.dtype(cfg.param_dtype)
    ke, kb, kh = jax.random.split(rng, 3)
    blocks = [
        init_mamba_block(k, cfg, dtype) for k in jax.random.split(kb, cfg.num_layers)
    ]
    return {
        "embed": {"tok": L.init_embedding(ke, cfg.padded_vocab, cfg.d_model, dtype)},
        "blocks": jax.tree.map(lambda *xs: jnp.stack(xs), *blocks),
        "final_norm": L.init_norm(cfg.d_model, cfg.norm, dtype),
        "head": {
            "w": (jax.random.normal(kh, (cfg.d_model, cfg.padded_vocab)) * 0.02).astype(dtype)
        },
    }


# ---------------------------------------------------------------------------
# causal depthwise conv (width K) with optional carried state
# ---------------------------------------------------------------------------
def causal_conv(x: jax.Array, w: jax.Array, b: jax.Array, state: Optional[jax.Array] = None):
    """x (B, S, ch); w (K, ch); state (B, K-1, ch) from previous steps.
    Returns (y (B,S,ch), new_state (B, K-1, ch))."""
    K = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)  # (B, S+K-1, ch)
    y = jnp.zeros_like(x)
    for i in range(K):
        y = y + xp[:, i : i + x.shape[1]] * w[i]
    new_state = xp[:, -(K - 1) :] if K > 1 else state
    return jax.nn.silu(y + b), new_state


# ---------------------------------------------------------------------------
# SSD core
# ---------------------------------------------------------------------------
def ssd_chunked(
    x: jax.Array,  # (B, S, H, P)
    dt: jax.Array,  # (B, S, H) f32, already softplus'd
    A: jax.Array,  # (H,) f32, negative
    Bm: jax.Array,  # (B, S, N)
    Cm: jax.Array,  # (B, S, N)
    chunk: int,
    init_state: Optional[jax.Array] = None,  # (B, H, N, P)
) -> Tuple[jax.Array, jax.Array]:
    """Returns (y (B,S,H,P), final_state (B,H,N,P))."""
    Bsz, S, H, P = x.shape
    N = Bm.shape[-1]
    Q = min(chunk, S)
    pad = (-S) % Q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    Sp = S + pad
    nc = Sp // Q

    xc = x.reshape(Bsz, nc, Q, H, P)
    dtc = dt.reshape(Bsz, nc, Q, H).astype(jnp.float32)
    Bc = Bm.reshape(Bsz, nc, Q, N)
    Cc = Cm.reshape(Bsz, nc, Q, N)

    dA = dtc * A  # (B,nc,Q,H), negative
    Lc = jnp.cumsum(dA, axis=2)  # inclusive cumulative log-decay

    # ---- intra-chunk (quadratic within chunk only) ----
    CB = jnp.einsum("bcqn,bckn->bcqk", Cc.astype(jnp.float32), Bc.astype(jnp.float32))
    # decay matrix exp(L_t - L_s) for s <= t
    diff = Lc[:, :, :, None, :] - Lc[:, :, None, :, :]  # (B,nc,Q,Q,H) = L_t - L_s
    tri = jnp.tril(jnp.ones((Q, Q), bool))
    decay = jnp.where(tri[None, None, :, :, None], jnp.exp(diff), 0.0)
    M = CB[..., None] * decay * dtc[:, :, None, :, :]  # (B,nc,Q,Q,H); s axis=3
    y_intra = jnp.einsum("bcqkh,bckhp->bcqhp", M.astype(x.dtype), xc)

    # ---- chunk summary states ----
    w_end = jnp.exp(Lc[:, :, -1:, :] - Lc) * dtc  # (B,nc,Q,H)
    S_chunk = jnp.einsum(
        "bckn,bckh,bckhp->bchnp",
        Bc.astype(jnp.float32), w_end, xc.astype(jnp.float32),
    )  # (B,nc,H,N,P)
    chunk_decay = jnp.exp(Lc[:, :, -1, :])  # (B,nc,H)

    # ---- inter-chunk recurrence ----
    if init_state is None:
        init_state = jnp.zeros((Bsz, H, N, P), jnp.float32)

    def body(carry, xs):
        s_c, cdec, C_c, L_c = xs  # (B,H,N,P), (B,H), (B,Q,N), (B,Q,H)
        y_in = jnp.einsum(
            "bqn,bhnp,bqh->bqhp", C_c.astype(jnp.float32), carry, jnp.exp(L_c)
        )
        new = carry * cdec[:, :, None, None] + s_c
        return new, y_in

    xs = (
        S_chunk.transpose(1, 0, 2, 3, 4),
        chunk_decay.transpose(1, 0, 2),
        Cc.transpose(1, 0, 2, 3),
        Lc.transpose(1, 0, 2, 3),
    )
    final_state, y_inter = jax.lax.scan(body, init_state.astype(jnp.float32), xs)
    y_inter = y_inter.transpose(1, 0, 2, 3, 4).reshape(Bsz, Sp, H, P)

    y = y_intra.reshape(Bsz, Sp, H, P).astype(jnp.float32) + y_inter
    return y[:, :S].astype(x.dtype), final_state


def ssd_step(
    x: jax.Array,  # (B, 1, H, P)
    dt: jax.Array,  # (B, 1, H)
    A: jax.Array,
    Bm: jax.Array,  # (B, 1, N)
    Cm: jax.Array,
    state: jax.Array,  # (B, H, N, P) f32
) -> Tuple[jax.Array, jax.Array]:
    """Single-token recurrent update (decode)."""
    dt = dt[:, 0].astype(jnp.float32)  # (B,H)
    a = jnp.exp(dt * A)  # (B,H)
    dBx = jnp.einsum(
        "bn,bh,bhp->bhnp",
        Bm[:, 0].astype(jnp.float32), dt, x[:, 0].astype(jnp.float32),
    )
    new_state = state * a[:, :, None, None] + dBx
    y = jnp.einsum("bn,bhnp->bhp", Cm[:, 0].astype(jnp.float32), new_state)
    return y[:, None].astype(x.dtype), new_state


# ---------------------------------------------------------------------------
# full mamba block (norm -> projections -> conv -> SSD -> gated norm -> out)
# ---------------------------------------------------------------------------
def mamba_block_apply(
    bp: Params,
    cfg: ModelConfig,
    h: jax.Array,
    state: Optional[Params] = None,  # {"conv": (B,K-1,ch), "ssm": (B,H,N,P)}
) -> Tuple[jax.Array, Optional[Params]]:
    Bsz, S, d = h.shape
    H, P, N = cfg.ssm_num_heads, cfg.ssm_head_dim, cfg.ssm_state

    u = L.apply_norm(bp["ln"], h, cfg.norm)
    z = jnp.einsum("bsd,dhp->bshp", u, bp["in_z"])
    x = jnp.einsum("bsd,dhp->bshp", u, bp["in_x"])
    Bm = u @ bp["in_B"]
    Cm = u @ bp["in_C"]
    dt_raw = jnp.einsum("bsd,dh->bsh", u, bp["in_dt"])

    xbc = jnp.concatenate([x.reshape(Bsz, S, H * P), Bm, Cm], axis=-1)
    conv_state = state["conv"] if state is not None else None
    xbc, new_conv = causal_conv(xbc, bp["conv_w"], bp["conv_b"], conv_state)
    x = xbc[..., : H * P].reshape(Bsz, S, H, P)
    Bm = xbc[..., H * P : H * P + N]
    Cm = xbc[..., H * P + N :]

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + bp["dt_bias"])
    A = -jnp.exp(bp["A_log"])

    if state is not None and S == 1:
        y, new_ssm = ssd_step(x, dt, A, Bm, Cm, state["ssm"])
    else:
        init_state = state["ssm"] if state is not None else None
        y, new_ssm = ssd_chunked(x, dt, A, Bm, Cm, cfg.ssm_chunk, init_state)

    y = y + bp["D"].astype(y.dtype)[None, None, :, None] * x
    yf = y.reshape(Bsz, S, H * P) * jax.nn.silu(z.reshape(Bsz, S, H * P))
    yf = L.rms_norm(yf, bp["gnorm"]["w"])
    out = jnp.einsum("bshp,hpd->bsd", yf.reshape(Bsz, S, H, P), bp["out"])

    new_state = None
    if state is not None:
        new_state = {"conv": new_conv, "ssm": new_ssm}
    return h + out, new_state


# ---------------------------------------------------------------------------
# LM forward / serving
# ---------------------------------------------------------------------------
def forward_hidden(params: Params, cfg: ModelConfig, tokens: jax.Array) -> jax.Array:
    dtype = jnp.dtype(cfg.dtype)
    h = L.embed(params["embed"]["tok"], tokens, dtype)

    def body(h, bp):
        bp = fsdp.gather_block(bp)
        out, _ = mamba_block_apply(bp, cfg, h)
        return out, None

    if cfg.remat == "block":
        body = jax.checkpoint(body, prevent_cse=False)
    h, _ = jax.lax.scan(body, h, params["blocks"])
    return L.apply_norm(params["final_norm"], h, cfg.norm)


def forward(params: Params, cfg: ModelConfig, tokens: jax.Array) -> jax.Array:
    h = forward_hidden(params, cfg, tokens)
    return L.lm_logits(params["head"]["w"], h)


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=None) -> Params:
    """Mamba cache is O(1) in sequence length."""
    dtype = dtype or jnp.dtype(cfg.dtype)
    H, P, N, K = cfg.ssm_num_heads, cfg.ssm_head_dim, cfg.ssm_state, cfg.ssm_conv
    ch = H * P + 2 * N
    Lr = cfg.num_layers
    return {
        "conv": jnp.zeros((Lr, batch, K - 1, ch), dtype),
        "ssm": jnp.zeros((Lr, batch, H, N, P), jnp.float32),
        "len": jnp.zeros((), jnp.int32),
    }


def prefill(params: Params, cfg: ModelConfig, tokens: jax.Array, cache: Params):
    dtype = jnp.dtype(cfg.dtype)
    h = L.embed(params["embed"]["tok"], tokens, dtype)

    def body(h, xs):
        bp, conv_s, ssm_s = xs
        out, ns = mamba_block_apply(bp, cfg, h, state={"conv": conv_s, "ssm": ssm_s})
        return out, (ns["conv"], ns["ssm"])

    h, (convs, ssms) = jax.lax.scan(body, h, (params["blocks"], cache["conv"], cache["ssm"]))
    new_cache = {"conv": convs, "ssm": ssms, "len": cache["len"] + tokens.shape[1]}
    h = L.apply_norm(params["final_norm"], h, cfg.norm)
    return L.lm_logits(params["head"]["w"], h[:, -1:]), new_cache


def decode_step(params: Params, cfg: ModelConfig, token: jax.Array, cache: Params):
    return prefill(params, cfg, token, cache)

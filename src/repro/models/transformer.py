"""Decoder-only dense transformer (Llama/Qwen/Nemotron/Mistral families).

Layer-stacked params consumed via ``jax.lax.scan`` so HLO size is O(1) in
depth. Exposes the block-level API EBFT needs:

    init(rng, cfg)                   -> params
    forward(params, cfg, tokens)     -> (logits, final_hidden)
    block_apply(bp, cfg, h, pos)     -> h'          (one transformer block)
    prefill / decode_step / init_cache

Params layout (leading L axis on every "blocks" leaf):
    embed/tok            (V, d)
    blocks/ln1/w         (L, d)         blocks/ln2/w (L, d)
    blocks/attn/wq       (L, d, H, hd)  ... wk, wv (L, d, Hkv, hd), wo (L,H,hd,d)
    blocks/mlp/w_up      (L, d, ff)     w_gate (swiglu), w_down (L, ff, d)
    final_norm/w         (d,)
    head/w               (d, V)         (absent if tie_embeddings)
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed import fsdp
from repro.models import layers as L

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------
def init_block(key, cfg: ModelConfig, dtype) -> Params:
    k1, k2 = jax.random.split(key)
    d, hd = cfg.d_model, cfg.resolved_head_dim
    p: Params = {
        "ln1": L.init_norm(d, cfg.norm, dtype),
        "ln2": L.init_norm(d, cfg.norm, dtype),
        "attn": L.init_attention(k1, d, cfg.num_heads, cfg.num_kv_heads, hd, cfg.qkv_bias, dtype),
        "mlp": L.init_mlp(k2, d, cfg.d_ff, cfg.mlp_act, dtype),
    }
    return p


def _stack_blocks(keys, init_one) -> Params:
    blocks = [init_one(k) for k in keys]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *blocks)


def init(rng, cfg: ModelConfig) -> Params:
    dtype = jnp.dtype(cfg.param_dtype)
    ke, kb, kh = jax.random.split(rng, 3)
    params: Params = {
        "embed": {"tok": L.init_embedding(ke, cfg.padded_vocab, cfg.d_model, dtype)},
        "blocks": _stack_blocks(
            jax.random.split(kb, cfg.num_layers), lambda k: init_block(k, cfg, dtype)
        ),
        "final_norm": L.init_norm(cfg.d_model, cfg.norm, dtype),
    }
    if not cfg.tie_embeddings:
        params["head"] = {
            "w": L.init_embedding(kh, cfg.d_model, cfg.padded_vocab, dtype).T.reshape(
                cfg.d_model, cfg.padded_vocab
            )
        }
    return params


# ---------------------------------------------------------------------------
# single-block apply (shared by scan body and by EBFT's per-block fine-tuning)
# ---------------------------------------------------------------------------
def block_apply(
    bp: Params,
    cfg: ModelConfig,
    h: jax.Array,
    positions: jax.Array,
    cache: Optional[Params] = None,
) -> Tuple[jax.Array, Optional[Params]]:
    attn_in = L.apply_norm(bp["ln1"], h, cfg.norm)
    attn_out, new_cache = L.attention_block(
        bp["attn"],
        attn_in,
        positions=positions,
        rope_theta=cfg.rope_theta,
        causal=True,
        impl=cfg.attn_impl,
        chunk=cfg.attn_chunk,
        q_chunk=cfg.attn_q_chunk,
        cache=cache,
    )
    h = h + attn_out
    mlp_in = L.apply_norm(bp["ln2"], h, cfg.norm)
    h = h + L.mlp_block(bp["mlp"], mlp_in, cfg.mlp_act)
    return h, new_cache


# ---------------------------------------------------------------------------
# full forward (training): scan over blocks
# ---------------------------------------------------------------------------
def forward_hidden(
    params: Params, cfg: ModelConfig, tokens: jax.Array, positions: Optional[jax.Array] = None
) -> jax.Array:
    """tokens (B, S) -> final hidden states (B, S, d)."""
    dtype = jnp.dtype(cfg.dtype)
    h = L.embed(params["embed"]["tok"], tokens, dtype)
    if positions is None:
        positions = jnp.arange(tokens.shape[1])[None, :]

    def body(h, bp):
        bp = fsdp.gather_block(bp)  # ZeRO-3 gather-at-use (no-op w/o policy)
        out, _ = block_apply(bp, cfg, h, positions)
        return out, None

    if cfg.remat == "block":
        body = jax.checkpoint(body, prevent_cse=False)
    h, _ = jax.lax.scan(body, h, params["blocks"])
    return L.apply_norm(params["final_norm"], h, cfg.norm)


def logits_from_hidden(params: Params, cfg: ModelConfig, h: jax.Array) -> jax.Array:
    w = params["head"]["w"] if "head" in params else params["embed"]["tok"].T
    return L.lm_logits(w, h)


def forward(params: Params, cfg: ModelConfig, tokens: jax.Array) -> jax.Array:
    return logits_from_hidden(params, cfg, forward_hidden(params, cfg, tokens))


# ---------------------------------------------------------------------------
# KV-cache serving
# ---------------------------------------------------------------------------
def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=None) -> Params:
    dtype = dtype or jnp.dtype(cfg.dtype)
    hd = cfg.resolved_head_dim
    shape = (cfg.num_layers, batch, max_len, cfg.num_kv_heads, hd)
    return {
        "k": jnp.zeros(shape, dtype),
        "v": jnp.zeros(shape, dtype),
        "len": jnp.zeros((), jnp.int32),
    }


def _scan_with_cache(params, cfg, h, positions, cache):
    def body(carry, xs):
        h = carry
        bp, kc, vc = xs
        bp = fsdp.gather_block(bp)  # serve-path ZeRO-3 gather-at-use
        out, nc = block_apply(
            bp, cfg, h, positions, cache={"k": kc, "v": vc, "len": cache["len"]}
        )
        return out, (nc["k"], nc["v"])

    h, (ks, vs) = jax.lax.scan(body, h, (params["blocks"], cache["k"], cache["v"]))
    new_cache = {"k": ks, "v": vs, "len": cache["len"] + positions.shape[-1]}
    return h, new_cache


def prefill(params: Params, cfg: ModelConfig, tokens: jax.Array, cache: Params):
    """Run the prompt through the model, filling the cache. Returns
    (last-position logits, cache)."""
    dtype = jnp.dtype(cfg.dtype)
    h = L.embed(params["embed"]["tok"], tokens, dtype)
    positions = cache["len"] + jnp.arange(tokens.shape[1])[None, :]
    h, cache = _scan_with_cache(params, cfg, h, positions, cache)
    h = L.apply_norm(params["final_norm"], h, cfg.norm)
    return logits_from_hidden(params, cfg, h[:, -1:]), cache


def decode_step(params: Params, cfg: ModelConfig, token: jax.Array, cache: Params):
    """token (B, 1) -> (logits (B,1,V), new cache)."""
    return prefill(params, cfg, token, cache)

"""Encoder-decoder backbone (Seamless-M4T-medium text stack).

Per the assignment, the audio frontend is a STUB: ``input_specs()`` provides
precomputed frame embeddings (B, frames, d_model) that feed the encoder
directly; the text decoder consumes token ids. 12 encoder + 12 decoder
layers (the assignment's "12L" is per stack, matching the released model's
text encoder/decoder depths).

Decoder block = self-attn (causal) + cross-attn (over cached encoder
output) + MLP. Decode shapes run the decoder with a KV cache; the encoder
memory is computed once at prefill time.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed import fsdp
from repro.models import layers as L
from repro.models import transformer as T

Params = Dict[str, Any]


def init_dec_block(key, cfg: ModelConfig, dtype) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    d, hd = cfg.d_model, cfg.resolved_head_dim
    return {
        "ln1": L.init_norm(d, cfg.norm, dtype),
        "ln_x": L.init_norm(d, cfg.norm, dtype),
        "ln2": L.init_norm(d, cfg.norm, dtype),
        "attn": L.init_attention(k1, d, cfg.num_heads, cfg.num_kv_heads, hd, cfg.qkv_bias, dtype),
        "xattn": L.init_attention(k2, d, cfg.num_heads, cfg.num_kv_heads, hd, cfg.qkv_bias, dtype),
        "mlp": L.init_mlp(k3, d, cfg.d_ff, cfg.mlp_act, dtype),
    }


def init(rng, cfg: ModelConfig) -> Params:
    dtype = jnp.dtype(cfg.param_dtype)
    ke, kenc, kdec, kh = jax.random.split(rng, 4)
    return {
        "embed": {"tok": L.init_embedding(ke, cfg.padded_vocab, cfg.d_model, dtype)},
        "enc_blocks": T._stack_blocks(
            jax.random.split(kenc, cfg.enc_layers), lambda k: T.init_block(k, cfg, dtype)
        ),
        "dec_blocks": T._stack_blocks(
            jax.random.split(kdec, cfg.num_layers), lambda k: init_dec_block(k, cfg, dtype)
        ),
        "enc_norm": L.init_norm(cfg.d_model, cfg.norm, dtype),
        "final_norm": L.init_norm(cfg.d_model, cfg.norm, dtype),
        "head": {
            "w": (jax.random.normal(kh, (cfg.d_model, cfg.padded_vocab)) * 0.02).astype(dtype)
        },
    }


# ---------------------------------------------------------------------------
def enc_block_apply(bp: Params, cfg: ModelConfig, h: jax.Array, positions: jax.Array) -> jax.Array:
    """One encoder block (bidirectional attention + MLP)."""
    attn_in = L.apply_norm(bp["ln1"], h, cfg.norm)
    attn_out, _ = L.attention_block(
        bp["attn"], attn_in, positions=positions, rope_theta=cfg.rope_theta,
        causal=False, impl=cfg.attn_impl, chunk=cfg.attn_chunk,
        q_chunk=cfg.attn_q_chunk,
    )
    h = h + attn_out
    mlp_in = L.apply_norm(bp["ln2"], h, cfg.norm)
    return h + L.mlp_block(bp["mlp"], mlp_in, cfg.mlp_act)


def encode(params: Params, cfg: ModelConfig, frames: jax.Array) -> jax.Array:
    """frames: precomputed (B, F, d) embeddings from the (stub) audio frontend."""
    positions = jnp.arange(frames.shape[1])[None, :]
    h = frames.astype(jnp.dtype(cfg.dtype))

    def body(h, bp):
        bp = fsdp.gather_block(bp)
        return enc_block_apply(bp, cfg, h, positions), None

    h, _ = jax.lax.scan(body, h, params["enc_blocks"])
    return L.apply_norm(params["enc_norm"], h, cfg.norm)


def dec_block_apply(bp, cfg, h, memory, positions, cache=None):
    attn_in = L.apply_norm(bp["ln1"], h, cfg.norm)
    attn_out, new_cache = L.attention_block(
        bp["attn"], attn_in, positions=positions, rope_theta=cfg.rope_theta,
        causal=True, impl=cfg.attn_impl, chunk=cfg.attn_chunk,
        q_chunk=cfg.attn_q_chunk, cache=cache,
    )
    h = h + attn_out
    # cross attention over encoder memory (no cache needed: memory static)
    x_in = L.apply_norm(bp["ln_x"], h, cfg.norm)
    q, _, _ = L.qkv_proj(bp["xattn"], x_in)
    mk = jnp.einsum("bsd,dhk->bshk", memory, bp["xattn"]["wk"])
    mv = jnp.einsum("bsd,dhk->bshk", memory, bp["xattn"]["wv"])
    if "bk" in bp["xattn"]:
        mk = mk + bp["xattn"]["bk"]
        mv = mv + bp["xattn"]["bv"]
    o = L.attend(q, mk, mv, causal=False, impl=cfg.attn_impl, chunk=cfg.attn_chunk,
                 q_chunk=cfg.attn_q_chunk)
    h = h + L.out_proj(bp["xattn"], o)
    mlp_in = L.apply_norm(bp["ln2"], h, cfg.norm)
    return h + L.mlp_block(bp["mlp"], mlp_in, cfg.mlp_act), new_cache


def decode_hidden(params, cfg, tokens, memory, cache=None):
    dtype = jnp.dtype(cfg.dtype)
    h = L.embed(params["embed"]["tok"], tokens, dtype)
    base = cache["len"] if cache is not None else 0
    positions = base + jnp.arange(tokens.shape[1])[None, :]

    if cache is None:
        def body(h, bp):
            bp = fsdp.gather_block(bp)
            out, _ = dec_block_apply(bp, cfg, h, memory, positions)
            return out, None
        h, _ = jax.lax.scan(body, h, params["dec_blocks"])
        return L.apply_norm(params["final_norm"], h, cfg.norm), None

    def body(h, xs):
        bp, kc, vc = xs
        out, nc = dec_block_apply(
            bp, cfg, h, memory, positions, cache={"k": kc, "v": vc, "len": cache["len"]}
        )
        return out, (nc["k"], nc["v"])

    h, (ks, vs) = jax.lax.scan(body, h, (params["dec_blocks"], cache["k"], cache["v"]))
    new_cache = {"k": ks, "v": vs, "len": cache["len"] + tokens.shape[1]}
    return L.apply_norm(params["final_norm"], h, cfg.norm), new_cache


def forward(params: Params, cfg: ModelConfig, tokens: jax.Array, frames: jax.Array) -> jax.Array:
    """Seq2seq training forward: (B,S_dec) tokens + (B,F,d) frames -> logits."""
    memory = encode(params, cfg, frames)
    h, _ = decode_hidden(params, cfg, tokens, memory)
    return L.lm_logits(params["head"]["w"], h)


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=None) -> Params:
    dtype = dtype or jnp.dtype(cfg.dtype)
    hd = cfg.resolved_head_dim
    shape = (cfg.num_layers, batch, max_len, cfg.num_kv_heads, hd)
    return {
        "k": jnp.zeros(shape, dtype),
        "v": jnp.zeros(shape, dtype),
        "len": jnp.zeros((), jnp.int32),
    }


def prefill(params, cfg, tokens, cache, memory):
    h, new_cache = decode_hidden(params, cfg, tokens, memory, cache)
    return L.lm_logits(params["head"]["w"], h[:, -1:]), new_cache


def decode_step(params, cfg, token, cache, memory):
    return prefill(params, cfg, token, cache, memory)

"""Zamba2-style hybrid: Mamba2 backbone + a single *shared* attention block
applied every ``hybrid_attn_every`` layers.

The shared block has one set of weights but a distinct KV cache per
invocation site (weights shared, activations not). We simplify Zamba2's
per-invocation LoRA diversification away (noted in DESIGN.md §7): the shared
block is applied verbatim at each site.

Layer schedule for num_layers=38, every=6:
    mamba x6, shared-attn, mamba x6, shared-attn, ... (6 invocations), mamba x2
Implemented as a scan over G groups of (K mamba layers + shared block) plus a
trailing scan for the remainder — HLO stays O(1) in depth.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed import fsdp
from repro.models import layers as L
from repro.models import ssm as S
from repro.models import transformer as T

Params = Dict[str, Any]


def schedule(cfg: ModelConfig) -> Tuple[int, int, int]:
    """-> (groups G, mamba-per-group K, trailing mamba layers R)."""
    K = cfg.hybrid_attn_every
    G = cfg.num_layers // K
    R = cfg.num_layers - G * K
    return G, K, R


def init(rng, cfg: ModelConfig) -> Params:
    dtype = jnp.dtype(cfg.param_dtype)
    ke, km, ks, kh = jax.random.split(rng, 4)
    G, K, R = schedule(cfg)

    mamba = [S.init_mamba_block(k, cfg, dtype) for k in jax.random.split(km, cfg.num_layers)]
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *mamba)
    grouped = jax.tree.map(lambda a: a[: G * K].reshape((G, K) + a.shape[1:]), stacked)
    trailing = jax.tree.map(lambda a: a[G * K :], stacked) if R else None

    params: Params = {
        "embed": {"tok": L.init_embedding(ke, cfg.padded_vocab, cfg.d_model, dtype)},
        "groups": grouped,  # leading (G, K) axes
        "shared": T.init_block(ks, cfg, dtype),  # one shared attention block
        "final_norm": L.init_norm(cfg.d_model, cfg.norm, dtype),
        "head": {
            "w": (jax.random.normal(kh, (cfg.d_model, cfg.padded_vocab)) * 0.02).astype(dtype)
        },
    }
    if trailing is not None:
        params["trailing"] = trailing
    return params


# ---------------------------------------------------------------------------
def _mamba_scan(cfg, h, stacked_bp, states=None):
    """Scan K mamba layers. states: optional {"conv": (K,...), "ssm": (K,...)}"""
    if states is None:
        def body(h, bp):
            bp = fsdp.gather_block(bp)
            out, _ = S.mamba_block_apply(bp, cfg, h)
            return out, None
        h, _ = jax.lax.scan(body, h, stacked_bp)
        return h, None

    def body(h, xs):
        bp, conv_s, ssm_s = xs
        out, ns = S.mamba_block_apply(bp, cfg, h, state={"conv": conv_s, "ssm": ssm_s})
        return out, (ns["conv"], ns["ssm"])

    h, (convs, ssms) = jax.lax.scan(body, h, (stacked_bp, states["conv"], states["ssm"]))
    return h, {"conv": convs, "ssm": ssms}


def forward_hidden(params: Params, cfg: ModelConfig, tokens: jax.Array) -> jax.Array:
    dtype = jnp.dtype(cfg.dtype)
    h = L.embed(params["embed"]["tok"], tokens, dtype)
    positions = jnp.arange(tokens.shape[1])[None, :]

    def group_body(h, group_bp):
        h, _ = _mamba_scan(cfg, h, group_bp)
        h, _ = T.block_apply(params["shared"], cfg, h, positions)
        return h, None

    if cfg.remat == "block":
        group_body = jax.checkpoint(group_body, prevent_cse=False)
    h, _ = jax.lax.scan(group_body, h, params["groups"])
    if "trailing" in params:
        h, _ = _mamba_scan(cfg, h, params["trailing"])
    return L.apply_norm(params["final_norm"], h, cfg.norm)


def forward(params: Params, cfg: ModelConfig, tokens: jax.Array) -> jax.Array:
    return L.lm_logits(params["head"]["w"], forward_hidden(params, cfg, tokens))


# ---------------------------------------------------------------------------
# serving: mamba states per layer + per-invocation KV caches for the shared blk
# ---------------------------------------------------------------------------
def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=None) -> Params:
    dtype = dtype or jnp.dtype(cfg.dtype)
    G, K, R = schedule(cfg)
    H, P, N, Kc = cfg.ssm_num_heads, cfg.ssm_head_dim, cfg.ssm_state, cfg.ssm_conv
    ch = H * P + 2 * N
    hd = cfg.resolved_head_dim
    cache: Params = {
        "groups": {
            "conv": jnp.zeros((G, K, batch, Kc - 1, ch), dtype),
            "ssm": jnp.zeros((G, K, batch, H, N, P), jnp.float32),
        },
        "shared_kv": {  # one KV cache per shared-block invocation
            "k": jnp.zeros((G, batch, max_len, cfg.num_kv_heads, hd), dtype),
            "v": jnp.zeros((G, batch, max_len, cfg.num_kv_heads, hd), dtype),
        },
        "len": jnp.zeros((), jnp.int32),
    }
    if R:
        cache["trailing"] = {
            "conv": jnp.zeros((R, batch, Kc - 1, ch), dtype),
            "ssm": jnp.zeros((R, batch, H, N, P), jnp.float32),
        }
    return cache


def prefill(params: Params, cfg: ModelConfig, tokens: jax.Array, cache: Params):
    dtype = jnp.dtype(cfg.dtype)
    h = L.embed(params["embed"]["tok"], tokens, dtype)
    positions = cache["len"] + jnp.arange(tokens.shape[1])[None, :]

    def group_body(h, xs):
        group_bp, conv_s, ssm_s, kc, vc = xs
        h, ns = _mamba_scan(cfg, h, group_bp, states={"conv": conv_s, "ssm": ssm_s})
        h, nkv = T.block_apply(
            params["shared"], cfg, h, positions,
            cache={"k": kc, "v": vc, "len": cache["len"]},
        )
        return h, (ns["conv"], ns["ssm"], nkv["k"], nkv["v"])

    xs = (
        params["groups"],
        cache["groups"]["conv"],
        cache["groups"]["ssm"],
        cache["shared_kv"]["k"],
        cache["shared_kv"]["v"],
    )
    h, (convs, ssms, ks, vs) = jax.lax.scan(group_body, h, xs)
    new_cache: Params = {
        "groups": {"conv": convs, "ssm": ssms},
        "shared_kv": {"k": ks, "v": vs},
        "len": cache["len"] + tokens.shape[1],
    }
    if "trailing" in params:
        h, ns = _mamba_scan(cfg, h, params["trailing"], states=cache["trailing"])
        new_cache["trailing"] = ns
    h = L.apply_norm(params["final_norm"], h, cfg.norm)
    return L.lm_logits(params["head"]["w"], h[:, -1:]), new_cache


def decode_step(params: Params, cfg: ModelConfig, token: jax.Array, cache: Params):
    return prefill(params, cfg, token, cache)

"""LLaVA-NeXT-style VLM: Mistral-7B LM backbone + stub vision frontend.

Per the assignment the vision tower is a STUB — ``input_specs()`` supplies
precomputed anyres patch embeddings (B, num_patches, d_model) which are
prepended to the token embeddings. Loss is computed on text positions only.
The LM backbone is the dense transformer (shared implementation).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed import fsdp
from repro.models import layers as L
from repro.models import transformer as T

Params = Dict[str, Any]


def init(rng, cfg: ModelConfig) -> Params:
    return T.init(rng, cfg)  # stub frontend has no trainable params here


def forward(params: Params, cfg: ModelConfig, tokens: jax.Array, patches: jax.Array) -> jax.Array:
    """tokens (B, S_text), patches (B, P, d) -> logits over text positions.

    The multimodal sequence is [patches ; text]; returned logits cover only
    the text segment (callers compute next-token loss on text).
    """
    dtype = jnp.dtype(cfg.dtype)
    tok_emb = L.embed(params["embed"]["tok"], tokens, dtype)
    h = jnp.concatenate([patches.astype(dtype), tok_emb], axis=1)
    positions = jnp.arange(h.shape[1])[None, :]

    def body(h, bp):
        bp = fsdp.gather_block(bp)
        out, _ = T.block_apply(bp, cfg, h, positions)
        return out, None

    if cfg.remat == "block":
        body = jax.checkpoint(body, prevent_cse=False)
    h, _ = jax.lax.scan(body, h, params["blocks"])
    h = L.apply_norm(params["final_norm"], h, cfg.norm)
    text_h = h[:, patches.shape[1] :]
    return T.logits_from_hidden(params, cfg, text_h)


# serving: the image prefix is prefilled into the same KV cache as text.
init_cache = T.init_cache


def prefill_multimodal(params, cfg, tokens, patches, cache):
    """Prefill [patches ; text] into the cache, return last-token logits."""
    dtype = jnp.dtype(cfg.dtype)
    tok_emb = L.embed(params["embed"]["tok"], tokens, dtype)
    h = jnp.concatenate([patches.astype(dtype), tok_emb], axis=1)
    positions = cache["len"] + jnp.arange(h.shape[1])[None, :]
    h, new_cache = T._scan_with_cache(params, cfg, h, positions, cache)
    h = L.apply_norm(params["final_norm"], h, cfg.norm)
    return T.logits_from_hidden(params, cfg, h[:, -1:]), new_cache


prefill = prefill_multimodal
decode_step = T.decode_step

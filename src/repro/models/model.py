"""Uniform model API over all families.

``build(cfg)`` returns a ``Model`` facade with a consistent interface:

    m.init(rng)                          -> params
    m.loss(params, batch)                -> (scalar loss, metrics dict)
    m.forward(params, batch)             -> logits
    m.init_serve_state(batch, max_len)   -> state   (KV cache / SSM states)
    m.prefill(params, batch, state)      -> (logits, state)
    m.decode_step(params, token, state)  -> (logits, state)
    m.input_specs(shape_cfg)             -> {name: ShapeDtypeStruct}

plus the block-level API consumed by EBFT (core/ebft.py):

    m.num_blocks                          (int; shared blocks counted once)
    m.get_block(params, i) / m.set_block(params, i, bp)
    m.apply_block(params, i, bp, h, positions) -> h'
    m.embed_tokens(params, batch) -> h0   (input hidden stream)
    m.finalize(params, h) -> logits       (final norm + head)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import encdec, hybrid, layers, moe, ssm, transformer, vlm

Params = Dict[str, Any]


def cross_entropy(logits: jax.Array, labels: jax.Array, mask: Optional[jax.Array] = None):
    """logits (B,S,V) f32, labels (B,S) int32. Returns mean nll over mask."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - ll
    if mask is None:
        return nll.mean()
    mask = mask.astype(jnp.float32)
    return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)


def _shift_loss(logits: jax.Array, tokens: jax.Array):
    """Next-token loss: predict tokens[:, 1:] from logits[:, :-1]."""
    return cross_entropy(logits[:, :-1], tokens[:, 1:])


@dataclasses.dataclass
class Model:
    cfg: ModelConfig
    init: Callable
    forward: Callable  # (params, batch) -> logits
    loss: Callable  # (params, batch) -> (loss, metrics)
    init_serve_state: Callable  # (batch, max_len) -> state
    prefill: Callable  # (params, batch, state) -> (logits, state)
    decode_step: Callable  # (params, token, state) -> (logits, state)
    input_specs: Callable  # (ShapeConfig) -> dict
    num_blocks: int
    get_block: Callable
    set_block: Callable
    apply_block: Callable
    embed_tokens: Callable
    finalize: Callable


# ---------------------------------------------------------------------------
# helpers for stacked-leaf block slicing
# ---------------------------------------------------------------------------
def _slice_tree(tree, i):
    return jax.tree.map(lambda a: a[i], tree)


def _set_tree(tree, i, sub):
    return jax.tree.map(lambda a, s: a.at[i].set(s.astype(a.dtype)), tree, sub)


# ---------------------------------------------------------------------------
def _token_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, jax.ShapeDtypeStruct]:
    B, S = shape.global_batch, shape.seq_len
    return {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}


def build(cfg: ModelConfig) -> Model:
    fam = cfg.family
    if fam in ("dense",):
        return _build_dense(cfg)
    if fam == "vlm":
        return _build_vlm(cfg)
    if fam == "moe":
        return _build_moe(cfg)
    if fam == "ssm":
        return _build_ssm(cfg)
    if fam == "hybrid":
        return _build_hybrid(cfg)
    if fam == "encdec":
        return _build_encdec(cfg)
    raise ValueError(f"unknown family {fam}")


# ---------------------------------------------------------------------------
def _build_dense(cfg: ModelConfig) -> Model:
    M = transformer

    def forward(params, batch):
        return M.forward(params, cfg, batch["tokens"])

    def loss(params, batch):
        logits = forward(params, batch)
        l = _shift_loss(logits, batch["tokens"])
        return l, {"nll": l}

    def prefill(params, batch, state):
        return M.prefill(params, cfg, batch["tokens"], state)

    def embed_tokens(params, batch):
        h = layers.embed(params["embed"]["tok"], batch["tokens"], jnp.dtype(cfg.dtype))
        pos = jnp.arange(batch["tokens"].shape[1])[None, :]
        return h, pos

    def apply_block(params, i, bp, h, positions):
        out, _ = M.block_apply(bp, cfg, h, positions)
        return out

    def finalize(params, h):
        h = layers.apply_norm(params["final_norm"], h, cfg.norm)
        return M.logits_from_hidden(params, cfg, h)

    return Model(
        cfg=cfg,
        init=lambda rng: M.init(rng, cfg),
        forward=forward,
        loss=loss,
        init_serve_state=lambda b, ml: M.init_cache(cfg, b, ml),
        prefill=prefill,
        decode_step=lambda p, t, s: M.decode_step(p, cfg, t, s),
        input_specs=lambda shape: _token_specs(cfg, shape),
        num_blocks=cfg.num_layers,
        get_block=lambda params, i: _slice_tree(params["blocks"], i),
        set_block=lambda params, i, bp: {
            **params, "blocks": _set_tree(params["blocks"], i, bp)
        },
        apply_block=apply_block,
        embed_tokens=embed_tokens,
        finalize=finalize,
    )


# ---------------------------------------------------------------------------
def _build_vlm(cfg: ModelConfig) -> Model:
    M = vlm

    def forward(params, batch):
        return M.forward(params, cfg, batch["tokens"], batch["patches"])

    def loss(params, batch):
        logits = forward(params, batch)
        l = _shift_loss(logits, batch["tokens"])
        return l, {"nll": l}

    def input_specs(shape: ShapeConfig):
        B, S = shape.global_batch, shape.seq_len
        P = min(cfg.frontend_len, S // 2)
        return {
            "tokens": jax.ShapeDtypeStruct((B, S - P), jnp.int32),
            "patches": jax.ShapeDtypeStruct((B, P, cfg.d_model), jnp.dtype(cfg.dtype)),
        }

    def embed_tokens(params, batch):
        dt = jnp.dtype(cfg.dtype)
        tok = layers.embed(params["embed"]["tok"], batch["tokens"], dt)
        h = jnp.concatenate([batch["patches"].astype(dt), tok], axis=1)
        pos = jnp.arange(h.shape[1])[None, :]
        return h, pos

    def apply_block(params, i, bp, h, positions):
        out, _ = transformer.block_apply(bp, cfg, h, positions)
        return out

    def finalize(params, h):
        h = layers.apply_norm(params["final_norm"], h, cfg.norm)
        return transformer.logits_from_hidden(params, cfg, h)

    def prefill(params, batch, state):
        return M.prefill_multimodal(params, cfg, batch["tokens"], batch["patches"], state)

    return Model(
        cfg=cfg,
        init=lambda rng: M.init(rng, cfg),
        forward=forward,
        loss=loss,
        init_serve_state=lambda b, ml: transformer.init_cache(cfg, b, ml),
        prefill=prefill,
        decode_step=lambda p, t, s: transformer.decode_step(p, cfg, t, s),
        input_specs=input_specs,
        num_blocks=cfg.num_layers,
        get_block=lambda params, i: _slice_tree(params["blocks"], i),
        set_block=lambda params, i, bp: {
            **params, "blocks": _set_tree(params["blocks"], i, bp)
        },
        apply_block=apply_block,
        embed_tokens=embed_tokens,
        finalize=finalize,
    )


# ---------------------------------------------------------------------------
def _build_moe(cfg: ModelConfig) -> Model:
    M = moe
    n_dense = cfg.moe_first_dense

    def forward(params, batch):
        return M.forward(params, cfg, batch["tokens"])

    def loss(params, batch):
        h, aux = M.forward_hidden(params, cfg, batch["tokens"])
        logits = transformer.logits_from_hidden(params, cfg, h)
        nll = _shift_loss(logits, batch["tokens"])
        l = nll + 0.01 * aux
        return l, {"nll": nll, "aux": aux}

    def embed_tokens(params, batch):
        h = layers.embed(params["embed"]["tok"], batch["tokens"], jnp.dtype(cfg.dtype))
        pos = jnp.arange(batch["tokens"].shape[1])[None, :]
        return h, pos

    def get_block(params, i):
        if i < n_dense:
            return _slice_tree(params["dense_blocks"], i)
        return _slice_tree(params["moe_blocks"], i - n_dense)

    def set_block(params, i, bp):
        if i < n_dense:
            return {**params, "dense_blocks": _set_tree(params["dense_blocks"], i, bp)}
        return {**params, "moe_blocks": _set_tree(params["moe_blocks"], i - n_dense, bp)}

    def apply_block(params, i, bp, h, positions):
        if i < n_dense:
            out, _ = transformer.block_apply(bp, cfg, h, positions)
            return out
        out, _, _ = M.moe_block_apply(bp, cfg, h, positions)
        return out

    def finalize(params, h):
        h = layers.apply_norm(params["final_norm"], h, cfg.norm)
        return transformer.logits_from_hidden(params, cfg, h)

    def prefill(params, batch, state):
        return M.prefill(params, cfg, batch["tokens"], state)

    return Model(
        cfg=cfg,
        init=lambda rng: M.init(rng, cfg),
        forward=forward,
        loss=loss,
        init_serve_state=lambda b, ml: M.init_cache(cfg, b, ml),
        prefill=prefill,
        decode_step=lambda p, t, s: M.decode_step(p, cfg, t, s),
        input_specs=lambda shape: _token_specs(cfg, shape),
        num_blocks=cfg.num_layers,
        get_block=get_block,
        set_block=set_block,
        apply_block=apply_block,
        embed_tokens=embed_tokens,
        finalize=finalize,
    )


# ---------------------------------------------------------------------------
def _build_ssm(cfg: ModelConfig) -> Model:
    M = ssm

    def forward(params, batch):
        return M.forward(params, cfg, batch["tokens"])

    def loss(params, batch):
        logits = forward(params, batch)
        l = _shift_loss(logits, batch["tokens"])
        return l, {"nll": l}

    def embed_tokens(params, batch):
        h = layers.embed(params["embed"]["tok"], batch["tokens"], jnp.dtype(cfg.dtype))
        pos = jnp.arange(batch["tokens"].shape[1])[None, :]
        return h, pos

    def apply_block(params, i, bp, h, positions):
        out, _ = M.mamba_block_apply(bp, cfg, h)
        return out

    def finalize(params, h):
        h = layers.apply_norm(params["final_norm"], h, cfg.norm)
        return layers.lm_logits(params["head"]["w"], h)

    def prefill(params, batch, state):
        return M.prefill(params, cfg, batch["tokens"], state)

    return Model(
        cfg=cfg,
        init=lambda rng: M.init(rng, cfg),
        forward=forward,
        loss=loss,
        init_serve_state=lambda b, ml: M.init_cache(cfg, b, ml),
        prefill=prefill,
        decode_step=lambda p, t, s: M.decode_step(p, cfg, t, s),
        input_specs=lambda shape: _token_specs(cfg, shape),
        num_blocks=cfg.num_layers,
        get_block=lambda params, i: _slice_tree(params["blocks"], i),
        set_block=lambda params, i, bp: {
            **params, "blocks": _set_tree(params["blocks"], i, bp)
        },
        apply_block=apply_block,
        embed_tokens=embed_tokens,
        finalize=finalize,
    )


# ---------------------------------------------------------------------------
def _build_hybrid(cfg: ModelConfig) -> Model:
    M = hybrid
    G, K, R = M.schedule(cfg)
    n_mamba = cfg.num_layers
    # EBFT block index space: [0, n_mamba) mamba blocks, n_mamba = shared block
    num_blocks = n_mamba + 1

    def forward(params, batch):
        return M.forward(params, cfg, batch["tokens"])

    def loss(params, batch):
        logits = forward(params, batch)
        l = _shift_loss(logits, batch["tokens"])
        return l, {"nll": l}

    def embed_tokens(params, batch):
        h = layers.embed(params["embed"]["tok"], batch["tokens"], jnp.dtype(cfg.dtype))
        pos = jnp.arange(batch["tokens"].shape[1])[None, :]
        return h, pos

    def get_block(params, i):
        if i == n_mamba:
            return params["shared"]
        if i < G * K:
            return jax.tree.map(lambda a: a[i // K, i % K], params["groups"])
        return _slice_tree(params["trailing"], i - G * K)

    def set_block(params, i, bp):
        if i == n_mamba:
            return {**params, "shared": bp}
        if i < G * K:
            return {
                **params,
                "groups": jax.tree.map(
                    lambda a, s: a.at[i // K, i % K].set(s.astype(a.dtype)), params["groups"], bp
                ),
            }
        return {**params, "trailing": _set_tree(params["trailing"], i - G * K, bp)}

    def apply_block(params, i, bp, h, positions):
        if i == n_mamba:
            out, _ = transformer.block_apply(bp, cfg, h, positions)
            return out
        out, _ = ssm.mamba_block_apply(bp, cfg, h)
        return out

    def finalize(params, h):
        h = layers.apply_norm(params["final_norm"], h, cfg.norm)
        return layers.lm_logits(params["head"]["w"], h)

    def prefill(params, batch, state):
        return M.prefill(params, cfg, batch["tokens"], state)

    return Model(
        cfg=cfg,
        init=lambda rng: M.init(rng, cfg),
        forward=forward,
        loss=loss,
        init_serve_state=lambda b, ml: M.init_cache(cfg, b, ml),
        prefill=prefill,
        decode_step=lambda p, t, s: M.decode_step(p, cfg, t, s),
        input_specs=lambda shape: _token_specs(cfg, shape),
        num_blocks=num_blocks,
        get_block=get_block,
        set_block=set_block,
        apply_block=apply_block,
        embed_tokens=embed_tokens,
        finalize=finalize,
    )


# ---------------------------------------------------------------------------
def _build_encdec(cfg: ModelConfig) -> Model:
    M = encdec
    n_enc, n_dec = cfg.enc_layers, cfg.num_layers

    def forward(params, batch):
        return M.forward(params, cfg, batch["tokens"], batch["frames"])

    def loss(params, batch):
        logits = forward(params, batch)
        l = _shift_loss(logits, batch["tokens"])
        return l, {"nll": l}

    def input_specs(shape: ShapeConfig):
        B, S = shape.global_batch, shape.seq_len
        F = max(cfg.frontend_len, S // 8)
        return {
            "tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
            "frames": jax.ShapeDtypeStruct((B, F, cfg.d_model), jnp.dtype(cfg.dtype)),
        }

    # serve state bundles the decoder KV cache with the encoder memory
    def init_serve_state(b, ml):
        F = max(cfg.frontend_len, ml // 8)
        return {
            "cache": M.init_cache(cfg, b, ml),
            "memory": jnp.zeros((b, F, cfg.d_model), jnp.dtype(cfg.dtype)),
        }

    def prefill(params, batch, state):
        memory = M.encode(params, cfg, batch["frames"])
        logits, cache = M.prefill(params, cfg, batch["tokens"], state["cache"], memory)
        return logits, {"cache": cache, "memory": memory}

    def decode_step(params, token, state):
        logits, cache = M.decode_step(params, cfg, token, state["cache"], state["memory"])
        return logits, {"cache": cache, "memory": state["memory"]}

    # EBFT block index space: encoder blocks [0, n_enc), decoder [n_enc, n_enc+n_dec)
    def get_block(params, i):
        if i < n_enc:
            return _slice_tree(params["enc_blocks"], i)
        return _slice_tree(params["dec_blocks"], i - n_enc)

    def set_block(params, i, bp):
        if i < n_enc:
            return {**params, "enc_blocks": _set_tree(params["enc_blocks"], i, bp)}
        return {**params, "dec_blocks": _set_tree(params["dec_blocks"], i - n_enc, bp)}

    def embed_tokens(params, batch):
        # EBFT fine-tunes the decoder stack; encoder memory comes along as aux.
        h = layers.embed(params["embed"]["tok"], batch["tokens"], jnp.dtype(cfg.dtype))
        pos = jnp.arange(batch["tokens"].shape[1])[None, :]
        return h, pos

    def apply_block(params, i, bp, h, positions, memory=None):
        if i < n_enc:
            return M.enc_block_apply(bp, cfg, h, positions)
        out, _ = M.dec_block_apply(bp, cfg, h, memory, positions)
        return out

    def finalize(params, h):
        h = layers.apply_norm(params["final_norm"], h, cfg.norm)
        return layers.lm_logits(params["head"]["w"], h)

    return Model(
        cfg=cfg,
        init=lambda rng: M.init(rng, cfg),
        forward=forward,
        loss=loss,
        init_serve_state=init_serve_state,
        prefill=prefill,
        decode_step=decode_step,
        input_specs=input_specs,
        num_blocks=n_enc + n_dec,
        get_block=get_block,
        set_block=set_block,
        apply_block=apply_block,
        embed_tokens=embed_tokens,
        finalize=finalize,
    )

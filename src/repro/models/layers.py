"""Core neural-net layers, pure functional JAX.

All params are plain dicts of jnp arrays. Block-stacked variants carry a
leading layer axis and are consumed through ``jax.lax.scan``.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed import act_sharding as AS

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# Normalisation
# ---------------------------------------------------------------------------
def rms_norm(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * w.astype(jnp.float32)).astype(dt)


def layer_norm(x: jax.Array, w: jax.Array, b: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    return (x * w.astype(jnp.float32) + b.astype(jnp.float32)).astype(dt)


def apply_norm(p: Params, x: jax.Array, kind: str) -> jax.Array:
    if kind == "layernorm":
        return layer_norm(x, p["w"], p["b"])
    return rms_norm(x, p["w"])


def init_norm(d: int, kind: str, dtype=jnp.float32) -> Params:
    p = {"w": jnp.ones((d,), dtype)}
    if kind == "layernorm":
        p["b"] = jnp.zeros((d,), dtype)
    return p


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------
def rope_table(positions: jax.Array, head_dim: int, theta: float) -> Tuple[jax.Array, jax.Array]:
    """positions: (...,) int -> cos/sin of shape (..., head_dim/2), f32."""
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs  # (..., half)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (B, S, H, hd); cos/sin: (B, S, hd/2) or (S, hd/2)."""
    dt = x.dtype
    x = x.astype(jnp.float32)
    x1, x2 = jnp.split(x, 2, axis=-1)
    if cos.ndim == 2:  # (S, half) -> broadcast over batch
        cos = cos[None]
        sin = sin[None]
    cos = cos[:, :, None, :]  # (B, S, 1, half)
    sin = sin[:, :, None, :]
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(dt)


# ---------------------------------------------------------------------------
# Attention (GQA, optional QKV bias). Three entry points:
#   - attend_full: training / prefill (causal or bidirectional)
#   - attend_decode: single-step query against a KV cache
# Both support "dot" (materialise scores) and "chunked" (online-softmax over
# KV chunks; memory O(S_q * chunk)) implementations.
# ---------------------------------------------------------------------------
def _repeat_kv(k: jax.Array, groups: int) -> jax.Array:
    """(B, S, Hkv, hd) -> (B, S, Hkv*groups, hd)."""
    if groups == 1:
        return k
    b, s, hkv, hd = k.shape
    k = jnp.broadcast_to(k[:, :, :, None, :], (b, s, hkv, groups, hd))
    return k.reshape(b, s, hkv * groups, hd)


def _softmax_attend(q, k, v, mask, scale):
    """q: (B,Sq,H,hd) k/v: (B,Sk,H,hd) mask: (Sq,Sk) or (B,Sq,Sk) or None."""
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    if mask is not None:
        if mask.ndim == 2:
            mask = mask[None, None]
        else:
            mask = mask[:, None]
        scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


def _chunked_attend(q, k, v, causal: bool, q_offset, chunk: int, scale):
    """Online-softmax over KV chunks: memory O(B*H*Sq*chunk), never (Sq,Sk).

    q: (B,Sq,H,hd); k,v: (B,Sk,H,hd). q_offset: position of q[0] within k's
    timeline (for causal masking during decode/prefill-with-cache).

    The chunk body is ``jax.checkpoint``ed: without it the scan's VJP
    stores the (B,H,Sq,chunk) probs for every chunk — O(Sq*Sk) residuals,
    exactly what flash-attention backward exists to avoid. With it the
    backward recomputes scores chunk-by-chunk (~+30% attention FLOPs for
    an O(S^2) -> O(S*chunk) residual-memory drop).

    Dots take bf16 inputs with f32 accumulation (MXU-native); the online
    softmax statistics (m, l, acc) stay f32.
    """
    b, sq, h, hd = q.shape
    sk = k.shape[1]
    n_chunks = max(1, (sk + chunk - 1) // chunk)
    pad = n_chunks * chunk - sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kc = k.reshape(b, n_chunks, chunk, h, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, n_chunks, chunk, h, hd).transpose(1, 0, 2, 3, 4)

    q_pos = q_offset + jnp.arange(sq)

    def body(carry, xs):
        m, l, acc = carry  # (B,H,Sq), (B,H,Sq), (B,Sq,H,hd)
        ci, kb, vb = xs
        scores = jnp.einsum(
            "bqhd,bkhd->bhqk", q, kb, preferred_element_type=jnp.float32
        ) * scale
        k_pos = ci * chunk + jnp.arange(chunk)
        valid = k_pos < sk
        msk = valid[None, :]
        if causal:
            msk = msk & (q_pos[:, None] >= k_pos[None, :])
        scores = jnp.where(msk[None, None], scores, -1e30)
        m_new = jnp.maximum(m, scores.max(axis=-1))
        p = jnp.exp(scores - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + p.sum(axis=-1)
        acc = acc * corr.transpose(0, 2, 1)[..., None] + jnp.einsum(
            "bhqk,bkhd->bqhd", p.astype(q.dtype), vb,
            preferred_element_type=jnp.float32,
        )
        return (m_new, l, acc), None

    body = jax.checkpoint(body, prevent_cse=False)

    # constrain the carry inits: without this GSPMD infers a replicated
    # carry from the constant zeros/full and drags the whole loop into
    # batch-replicated compute (see distributed/act_sharding.py)
    m0 = AS.constrain(jnp.full((b, h, sq), -jnp.inf, jnp.float32), "bhq")
    l0 = AS.constrain(jnp.zeros((b, h, sq), jnp.float32), "bhq")
    a0 = AS.constrain(jnp.zeros((b, sq, h, hd), jnp.float32), "bqhd")
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0), (jnp.arange(n_chunks), kc, vc)
    )
    out = acc / jnp.maximum(l, 1e-30).transpose(0, 2, 1)[..., None]
    return out.astype(q.dtype)


def attend(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool,
    impl: str = "dot",
    chunk: int = 1024,
    q_chunk: int = 0,
    q_offset=0,
) -> jax.Array:
    """Grouped-query attention core. q: (B,Sq,H,hd), k/v: (B,Sk,Hkv,hd).

    ``q_chunk`` > 0 blocks the query axis too (32k-prefill memory: keeps
    the online-softmax probs tensor at (B,H,q_chunk,chunk) instead of
    (B,H,Sq,chunk)).
    """
    h, hkv = q.shape[2], k.shape[2]
    scale = 1.0 / math.sqrt(q.shape[-1])
    k = _repeat_kv(k, h // hkv)
    v = _repeat_kv(v, h // hkv)
    if impl == "flash" and jax.default_backend() == "tpu":
        from repro import kernels

        off = q_offset if isinstance(q_offset, int) else 0
        return kernels.dispatch("flash_attention", q, k, v, layout="bshd",
                                causal=causal, q_offset=off)
    if impl == "chunked" or impl == "flash":
        # portable equivalent of the Pallas flash kernel (same online-
        # softmax recurrence), used off-TPU
        sq = q.shape[1]
        if q_chunk and sq > q_chunk:
            assert sq % q_chunk == 0, (sq, q_chunk)
            nq = sq // q_chunk
            qb = q.reshape(q.shape[0], nq, q_chunk, *q.shape[2:]).transpose(
                1, 0, 2, 3, 4
            )
            offs = q_offset + jnp.arange(nq) * q_chunk

            def one(args):
                qi, off = args
                return _chunked_attend(qi, k, v, causal, off, chunk, scale)

            out = jax.lax.map(one, (qb, offs))  # (nq, B, q_chunk, H, hd)
            return out.transpose(1, 0, 2, 3, 4).reshape(q.shape)
        return _chunked_attend(q, k, v, causal, q_offset, chunk, scale)
    sq, sk = q.shape[1], k.shape[1]
    mask = None
    if causal:
        q_pos = q_offset + jnp.arange(sq)
        mask = q_pos[:, None] >= jnp.arange(sk)[None, :]
    return _softmax_attend(q, k, v, mask, scale)


# ---------------------------------------------------------------------------
# Attention block params + apply
# ---------------------------------------------------------------------------
def init_attention(key, d: int, h: int, hkv: int, hd: int, bias: bool, dtype) -> Params:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s = 1.0 / math.sqrt(d)
    p = {
        "wq": (jax.random.normal(k1, (d, h, hd)) * s).astype(dtype),
        "wk": (jax.random.normal(k2, (d, hkv, hd)) * s).astype(dtype),
        "wv": (jax.random.normal(k3, (d, hkv, hd)) * s).astype(dtype),
        "wo": (jax.random.normal(k4, (h, hd, d)) * (1.0 / math.sqrt(h * hd))).astype(dtype),
    }
    if bias:
        p["bq"] = jnp.zeros((h, hd), dtype)
        p["bk"] = jnp.zeros((hkv, hd), dtype)
        p["bv"] = jnp.zeros((hkv, hd), dtype)
    return p


def qkv_proj(p: Params, x: jax.Array) -> Tuple[jax.Array, jax.Array, jax.Array]:
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if "bq" in p:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    return q, k, v


def out_proj(p: Params, o: jax.Array) -> jax.Array:
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"])


def attention_block(
    p: Params,
    x: jax.Array,
    *,
    positions: jax.Array,
    rope_theta: float,
    causal: bool = True,
    impl: str = "dot",
    chunk: int = 1024,
    q_chunk: int = 0,
    cache: Optional[Params] = None,
) -> Tuple[jax.Array, Optional[Params]]:
    """Full attention sub-block (no norm/residual). If ``cache`` is given it is
    a dict {"k": (B,Smax,Hkv,hd), "v": ..., "len": ()} — decode/prefill append.
    """
    hd = p["wq"].shape[-1]
    q, k, v = qkv_proj(p, x)
    cos, sin = rope_table(positions, hd, rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)

    if cache is None:
        o = attend(q, k, v, causal=causal, impl=impl, chunk=chunk, q_chunk=q_chunk)
        return out_proj(p, o), None

    # append to cache at position cache["len"]
    start = cache["len"]
    kc = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype), (0, start, 0, 0))
    vc = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype), (0, start, 0, 0))
    new_cache = {"k": kc, "v": vc, "len": start + x.shape[1]}
    o = attend(
        q, kc.astype(q.dtype), vc.astype(q.dtype),
        causal=True, impl="chunked" if impl != "dot" else "dot",
        chunk=chunk, q_chunk=q_chunk, q_offset=start,
    )
    return out_proj(p, o), new_cache


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------
def init_mlp(key, d: int, ff: int, act: str, dtype) -> Params:
    ks = jax.random.split(key, 3)
    s_in = 1.0 / math.sqrt(d)
    s_out = 1.0 / math.sqrt(ff)
    p = {
        "w_up": (jax.random.normal(ks[0], (d, ff)) * s_in).astype(dtype),
        "w_down": (jax.random.normal(ks[1], (ff, d)) * s_out).astype(dtype),
    }
    if act == "swiglu":
        p["w_gate"] = (jax.random.normal(ks[2], (d, ff)) * s_in).astype(dtype)
    return p


def mlp_block(p: Params, x: jax.Array, act: str) -> jax.Array:
    up = x @ p["w_up"]
    if act == "swiglu":
        gate = x @ p["w_gate"]
        hidden = jax.nn.silu(gate) * up
    elif act == "sq_relu":
        hidden = jnp.square(jax.nn.relu(up))
    else:  # gelu
        hidden = jax.nn.gelu(up)
    return hidden @ p["w_down"]


# ---------------------------------------------------------------------------
# Embedding / head
# ---------------------------------------------------------------------------
def init_embedding(key, vocab: int, d: int, dtype) -> jax.Array:
    return (jax.random.normal(key, (vocab, d)) * 0.02).astype(dtype)


def embed(tok_emb: jax.Array, tokens: jax.Array, dtype) -> jax.Array:
    return tok_emb[tokens].astype(dtype)


def lm_logits(head_w: jax.Array, x: jax.Array) -> jax.Array:
    return jnp.einsum("bsd,dv->bsv", x, head_w).astype(jnp.float32)

"""Mixture-of-Experts transformer (DeepSeek-MoE-16B, Kimi-K2 families).

Fine-grained MoE: ``moe_num_experts`` routed experts with top-k softmax
gating (renormalised over the selected k), plus ``moe_num_shared`` shared
experts that process every token. The first ``moe_first_dense`` layers are
ordinary dense blocks (DeepSeek/Kimi convention).

Dispatch is capacity-based scatter/gather (statically shaped, GSPMD
shardable): tokens are scattered into an (E, C, d) buffer sharded over the
"model" (expert-parallel) axis, expert FFNs run as batched einsums, results
gather back weighted by the gates. Overflowed tokens fall through to the
residual (standard capacity-drop semantics; capacity factor configurable).
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed import fsdp
from repro.models import layers as L
from repro.models import transformer as T

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------
def init_moe_mlp(key, cfg: ModelConfig, dtype) -> Params:
    d, ff, E = cfg.d_model, cfg.moe_d_ff, cfg.moe_num_experts
    ks = jax.random.split(key, 5)
    s_in, s_out = 1.0 / math.sqrt(d), 1.0 / math.sqrt(ff)
    p: Params = {
        "router": {"w": (jax.random.normal(ks[0], (d, E)) * s_in).astype(jnp.float32)},
        "experts": {
            "w_gate": (jax.random.normal(ks[1], (E, d, ff)) * s_in).astype(dtype),
            "w_up": (jax.random.normal(ks[2], (E, d, ff)) * s_in).astype(dtype),
            "w_down": (jax.random.normal(ks[3], (E, ff, d)) * s_out).astype(dtype),
        },
    }
    if cfg.moe_num_shared:
        p["shared"] = L.init_mlp(ks[4], d, ff * cfg.moe_num_shared, "swiglu", dtype)
    return p


def init_moe_block(key, cfg: ModelConfig, dtype) -> Params:
    k1, k2 = jax.random.split(key)
    d, hd = cfg.d_model, cfg.resolved_head_dim
    return {
        "ln1": L.init_norm(d, cfg.norm, dtype),
        "ln2": L.init_norm(d, cfg.norm, dtype),
        "attn": L.init_attention(k1, d, cfg.num_heads, cfg.num_kv_heads, hd, cfg.qkv_bias, dtype),
        "moe": init_moe_mlp(k2, cfg, dtype),
    }


def init(rng, cfg: ModelConfig) -> Params:
    dtype = jnp.dtype(cfg.param_dtype)
    ke, kd, km, kh = jax.random.split(rng, 4)
    n_dense, n_moe = cfg.moe_first_dense, cfg.num_layers - cfg.moe_first_dense
    params: Params = {
        "embed": {"tok": L.init_embedding(ke, cfg.padded_vocab, cfg.d_model, dtype)},
        "moe_blocks": T._stack_blocks(
            jax.random.split(km, n_moe), lambda k: init_moe_block(k, cfg, dtype)
        ),
        "final_norm": L.init_norm(cfg.d_model, cfg.norm, dtype),
    }
    if n_dense:
        params["dense_blocks"] = T._stack_blocks(
            jax.random.split(kd, n_dense), lambda k: T.init_block(k, cfg, dtype)
        )
    if not cfg.tie_embeddings:
        params["head"] = {
            "w": (jax.random.normal(kh, (cfg.d_model, cfg.padded_vocab)) * 0.02).astype(dtype)
        }
    return params


# ---------------------------------------------------------------------------
# routed expert dispatch
# ---------------------------------------------------------------------------
def route(router_w: jax.Array, xf: jax.Array, k: int) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """xf (T, d) -> (gates (T,k) f32, expert idx (T,k) i32, probs (T,E) f32)."""
    logits = (xf.astype(jnp.float32) @ router_w).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    return gates, idx, probs


def _dispatch_group(p: Params, xf: jax.Array, cfg: ModelConfig) -> Tuple[jax.Array, jax.Array]:
    """Route/dispatch/combine for one token group. xf (T, d) -> (out, aux)."""
    T_, d = xf.shape
    E, k = cfg.moe_num_experts, cfg.moe_top_k

    gates, idx, probs = route(p["router"]["w"], xf, k)

    # load-balance auxiliary loss (Switch-style)
    me = probs.mean(axis=0)  # (E,)
    ce = jnp.zeros((E,), jnp.float32).at[idx.reshape(-1)].add(1.0) / (T_ * k)
    aux = E * jnp.sum(me * ce)

    # capacity-based dispatch (per-group capacity)
    C = max(1, int(cfg.moe_capacity_factor * T_ * k / E))
    flat_idx = idx.reshape(-1)  # (T*k,)
    onehot = jax.nn.one_hot(flat_idx, E, dtype=jnp.int32)  # (T*k, E)
    pos = jnp.cumsum(onehot, axis=0) - 1  # position within expert
    pos = jnp.take_along_axis(pos, flat_idx[:, None], axis=1)[:, 0]  # (T*k,)
    keep = pos < C
    pos = jnp.where(keep, pos, 0)

    x_rep = jnp.repeat(xf, k, axis=0)  # (T*k, d)
    disp = jnp.zeros((E, C, d), xf.dtype)
    disp = disp.at[flat_idx, pos].add(
        jnp.where(keep[:, None], x_rep, 0).astype(xf.dtype), mode="drop"
    )

    # expert FFN (swiglu), batched over E
    ew = p["experts"]
    gate_h = jnp.einsum("ecd,edf->ecf", disp, ew["w_gate"])
    up_h = jnp.einsum("ecd,edf->ecf", disp, ew["w_up"])
    h = jax.nn.silu(gate_h) * up_h
    eout = jnp.einsum("ecf,efd->ecd", h, ew["w_down"])  # (E, C, d)

    # gather back + combine with gates
    slots = eout[flat_idx, pos]  # (T*k, d)
    slots = jnp.where(keep[:, None], slots, 0)
    out = (slots.reshape(T_, k, d) * gates[..., None].astype(xf.dtype)).sum(axis=1)
    return out, aux


def moe_mlp(p: Params, x: jax.Array, cfg: ModelConfig) -> Tuple[jax.Array, jax.Array]:
    """x (B, S, d) -> (out (B, S, d), aux load-balance loss scalar).

    Tokens are dispatched within ``moe_dispatch_groups`` independent groups
    (see configs/base.py) — at scale G = data shards so the dispatch buffer
    is (G, E, C_local, d), sharded (data, EP, ., .), with local capacity.
    """
    B, S, d = x.shape
    G = max(1, cfg.moe_dispatch_groups)
    T_ = B * S
    assert T_ % G == 0, (T_, G)
    xg = x.reshape(G, T_ // G, d)
    out, aux = jax.vmap(lambda xf: _dispatch_group(p, xf, cfg))(xg)
    out = out.reshape(B, S, d)

    if "shared" in p:
        out = out + L.mlp_block(p["shared"], x.reshape(T_, d), "swiglu").reshape(B, S, d)
    return out, aux.mean()


def moe_block_apply(
    bp: Params,
    cfg: ModelConfig,
    h: jax.Array,
    positions: jax.Array,
    cache: Optional[Params] = None,
) -> Tuple[jax.Array, Optional[Params], jax.Array]:
    attn_in = L.apply_norm(bp["ln1"], h, cfg.norm)
    attn_out, new_cache = L.attention_block(
        bp["attn"], attn_in, positions=positions, rope_theta=cfg.rope_theta,
        causal=True, impl=cfg.attn_impl, chunk=cfg.attn_chunk,
        q_chunk=cfg.attn_q_chunk, cache=cache,
    )
    h = h + attn_out
    mlp_in = L.apply_norm(bp["ln2"], h, cfg.norm)
    mlp_out, aux = moe_mlp(bp["moe"], mlp_in, cfg)
    return h + mlp_out, new_cache, aux


# ---------------------------------------------------------------------------
# full forward
# ---------------------------------------------------------------------------
def forward_hidden(params: Params, cfg: ModelConfig, tokens: jax.Array):
    dtype = jnp.dtype(cfg.dtype)
    h = L.embed(params["embed"]["tok"], tokens, dtype)
    positions = jnp.arange(tokens.shape[1])[None, :]

    aux_total = jnp.zeros((), jnp.float32)
    if "dense_blocks" in params:
        def dbody(h, bp):
            bp = fsdp.gather_block(bp)
            out, _ = T.block_apply(bp, cfg, h, positions)
            return out, None
        h, _ = jax.lax.scan(dbody, h, params["dense_blocks"])

    def body(carry, bp):
        h, aux = carry
        bp = fsdp.gather_block(bp)
        out, _, a = moe_block_apply(bp, cfg, h, positions)
        return (out, aux + a), None

    if cfg.remat == "block":
        body = jax.checkpoint(body, prevent_cse=False)
    (h, aux_total), _ = jax.lax.scan(body, (h, aux_total), params["moe_blocks"])
    h = L.apply_norm(params["final_norm"], h, cfg.norm)
    return h, aux_total


def forward(params: Params, cfg: ModelConfig, tokens: jax.Array) -> jax.Array:
    h, _ = forward_hidden(params, cfg, tokens)
    return T.logits_from_hidden(params, cfg, h)


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------
def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=None) -> Params:
    dtype = dtype or jnp.dtype(cfg.dtype)
    hd = cfg.resolved_head_dim
    n_dense, n_moe = cfg.moe_first_dense, cfg.num_layers - cfg.moe_first_dense
    cache: Params = {
        "moe": {
            "k": jnp.zeros((n_moe, batch, max_len, cfg.num_kv_heads, hd), dtype),
            "v": jnp.zeros((n_moe, batch, max_len, cfg.num_kv_heads, hd), dtype),
        },
        "len": jnp.zeros((), jnp.int32),
    }
    if n_dense:
        cache["dense"] = {
            "k": jnp.zeros((n_dense, batch, max_len, cfg.num_kv_heads, hd), dtype),
            "v": jnp.zeros((n_dense, batch, max_len, cfg.num_kv_heads, hd), dtype),
        }
    return cache


def prefill(params: Params, cfg: ModelConfig, tokens: jax.Array, cache: Params):
    dtype = jnp.dtype(cfg.dtype)
    h = L.embed(params["embed"]["tok"], tokens, dtype)
    positions = cache["len"] + jnp.arange(tokens.shape[1])[None, :]

    new_cache: Params = {"len": cache["len"] + tokens.shape[1]}
    if "dense_blocks" in params:
        def dbody(h, xs):
            bp, kc, vc = xs
            bp = fsdp.gather_block(bp)
            out, nc = T.block_apply(
                bp, cfg, h, positions, cache={"k": kc, "v": vc, "len": cache["len"]}
            )
            return out, (nc["k"], nc["v"])
        h, (ks, vs) = jax.lax.scan(
            dbody, h, (params["dense_blocks"], cache["dense"]["k"], cache["dense"]["v"])
        )
        new_cache["dense"] = {"k": ks, "v": vs}

    def body(h, xs):
        bp, kc, vc = xs
        bp = fsdp.gather_block(bp)
        out, nc, _ = moe_block_apply(
            bp, cfg, h, positions, cache={"k": kc, "v": vc, "len": cache["len"]}
        )
        return out, (nc["k"], nc["v"])

    h, (ks, vs) = jax.lax.scan(
        body, h, (params["moe_blocks"], cache["moe"]["k"], cache["moe"]["v"])
    )
    new_cache["moe"] = {"k": ks, "v": vs}
    h = L.apply_norm(params["final_norm"], h, cfg.norm)
    return T.logits_from_hidden(params, cfg, h[:, -1:]), new_cache


def decode_step(params: Params, cfg: ModelConfig, token: jax.Array, cache: Params):
    return prefill(params, cfg, token, cache)

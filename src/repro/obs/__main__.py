"""CLI: ``python -m repro.obs {report,validate} <artifact>``.

``report`` renders a run artifact (JSON summary or JSONL event stream)
as a text trace tree + metric summary, or re-emits it as JSON with
``--json``. ``validate`` checks the manifest schema and any required
top-level keys — the CI gate for ``BENCH_ebft.json``::

    python -m repro.obs report BENCH_ebft.json
    python -m repro.obs validate BENCH_ebft.json --require blocks phases
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.obs.report import render_text
from repro.obs.run import validate_payload
from repro.obs.sinks import load_artifact


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs",
        description="Render and validate repro.obs run artifacts "
                    "(docs/OBSERVABILITY.md).",
    )
    sub = ap.add_subparsers(dest="cmd", required=True)

    rp = sub.add_parser("report", help="render a run artifact")
    rp.add_argument("artifact", help="JSON summary or JSONL event stream")
    rp.add_argument("--json", action="store_true",
                    help="emit the loaded payload as JSON instead of text")

    vp = sub.add_parser("validate", help="schema-check a run artifact")
    vp.add_argument("artifact")
    vp.add_argument("--require", nargs="*", default=[], metavar="KEY",
                    help="top-level keys the artifact must carry "
                         "(e.g. blocks phases)")
    vp.add_argument("--max-dispatches-per-block", type=int, default=None,
                    metavar="N",
                    help="fail if dispatch.per_block_max exceeds N "
                         "(the fused-walk dispatch budget, docs/PERF.md)")
    vp.add_argument("--require-cache-hits", action="store_true",
                    help="fail unless kernel_tuning shows a fully warm "
                         "autotuner cache: hits >= 1, zero misses/"
                         "searches/search seconds (docs/PERF.md)")

    args = ap.parse_args(argv)
    try:
        payload = load_artifact(args.artifact)
    except (OSError, json.JSONDecodeError) as e:
        print(f"error: cannot load {args.artifact}: {e}", file=sys.stderr)
        return 2

    if args.cmd == "report":
        try:
            if args.json:
                print(json.dumps(payload, indent=2))
            else:
                print(render_text(payload))
        except BrokenPipeError:  # report | head is the expected use
            sys.stderr.close()
        return 0

    problems = validate_payload(
        payload, require=args.require,
        max_dispatches_per_block=args.max_dispatches_per_block,
        require_cache_hits=args.require_cache_hits,
    )
    if problems:
        for p in problems:
            print(f"INVALID {args.artifact}: {p}")
        return 1
    print(f"OK {args.artifact}")
    return 0


if __name__ == "__main__":
    sys.exit(main())

"""Output sinks: JSONL event stream, JSON summaries, console lines.

Two machine formats (docs/OBSERVABILITY.md §Sinks):

  * **JSONL** — one event object per line, appended as spans close and
    metrics update; survives crashes mid-run and streams to log
    shippers. First line is always the run manifest
    (``{"type": "manifest", ...}``).
  * **JSON summary** — a single document written at ``Run.finish()``:
    manifest + metric summaries + the span tree (the ``BENCH_*.json``
    artifact format the report CLI renders).

``ConsoleSink`` keeps the drivers' human-readable output: it is just a
line printer, but routing through it means the same call sites feed
humans and machines.
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, Optional


def _jsonable(v):
    """Best-effort JSON coercion for numpy/jax scalars in attrs."""
    try:
        json.dumps(v)
        return v
    except TypeError:
        try:
            return float(v)
        except Exception:
            return repr(v)


class JsonlSink:
    """Append-mode JSONL event writer (flushes per event: crash-safe)."""

    def __init__(self, path: str):
        self.path = path
        os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
        self._f = open(path, "a")

    def emit(self, event: Dict[str, Any]) -> None:
        self._f.write(json.dumps(
            {k: _jsonable(v) for k, v in event.items()}) + "\n")
        self._f.flush()

    def close(self) -> None:
        if self._f:
            self._f.close()
            self._f = None


class ConsoleSink:
    """Human-readable line printer (the drivers' stdout reporting)."""

    def emit_line(self, line: str) -> None:
        print(line, flush=True)


def write_summary(path: str, payload: Dict[str, Any]) -> str:
    """Write a JSON-summary artifact; returns the path."""
    d = os.path.dirname(os.path.abspath(path))
    if d:
        os.makedirs(d, exist_ok=True)
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, default=_jsonable)
        f.write("\n")
    return path


def read_jsonl(path: str):
    """Parse a JSONL event stream back into a list of event dicts."""
    events = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                events.append(json.loads(line))
    return events


def load_artifact(path: str) -> Optional[Dict[str, Any]]:
    """Load either artifact format: a JSON-summary document, or a JSONL
    event stream (reassembled into {"manifest", "events"})."""
    with open(path) as f:
        text = f.read()
    try:
        doc = json.loads(text)
        if isinstance(doc, dict) and doc.get("type") != "manifest":
            return doc  # a summary document spans the whole file
    except json.JSONDecodeError:
        pass  # multiple lines: JSONL
    events = [json.loads(ln) for ln in text.splitlines() if ln.strip()]
    manifest: Dict[str, Any] = {}
    for ev in events:
        if ev.get("type") == "manifest":
            manifest = ev.get("manifest", {})
            break
    return {"manifest": manifest, "events": events}

"""Render run artifacts: trace tree + metric summaries, text or JSON.

Accepts both artifact formats (JSON summary / JSONL stream). For the
summary format the span forest is rendered as an indented tree; for the
raw event stream, span-end events are shown flat, indented by recorded
depth (they arrive post-order, so the tree is not reconstructed).
"""
from __future__ import annotations

from typing import Any, Dict, List


def _fmt_val(v: Any) -> str:
    if isinstance(v, float):
        if v != 0 and (abs(v) < 1e-3 or abs(v) >= 1e6):
            return f"{v:.3e}"
        return f"{v:.4g}"
    return str(v)


def _fmt_attrs(attrs: Dict[str, Any]) -> str:
    if not attrs:
        return ""
    body = ", ".join(f"{k}={_fmt_val(v)}" for k, v in attrs.items())
    return f"  [{body}]"


def _span_lines(node: Dict[str, Any], depth: int, out: List[str]) -> None:
    out.append(
        f"{'  ' * depth}{node.get('name', '?'):<{max(40 - 2 * depth, 8)}}"
        f"{node.get('duration_s', 0.0):>10.3f}s"
        + _fmt_attrs(node.get("attrs", {}))
    )
    for child in node.get("children", []):
        _span_lines(child, depth + 1, out)


def _metric_line(name: str, s: Dict[str, Any]) -> str:
    kind = s.get("kind", "?")
    if kind == "counter":
        body = f"value={_fmt_val(s.get('value'))}"
    elif kind == "gauge":
        body = (f"last={_fmt_val(s.get('last'))} min={_fmt_val(s.get('min'))} "
                f"max={_fmt_val(s.get('max'))}")
    elif kind == "histogram":
        body = (f"n={s.get('count')} mean={_fmt_val(s.get('mean'))} "
                f"p50={_fmt_val(s.get('p50'))} p99={_fmt_val(s.get('p99'))} "
                f"max={_fmt_val(s.get('max'))}")
    elif kind == "series":
        body = (f"n={s.get('n')} first={_fmt_val(s.get('first'))} "
                f"last={_fmt_val(s.get('last'))} min={_fmt_val(s.get('min'))}")
    else:
        body = " ".join(f"{k}={_fmt_val(v)}" for k, v in s.items())
    return f"  {name:<44} {kind:<9} {body}"


def render_text(payload: Dict[str, Any]) -> str:
    lines: List[str] = []
    manifest = payload.get("manifest", {})
    if manifest:
        lines.append(f"run: {manifest.get('name', '?')}")
        for key in ("config", "method", "sparsity", "pattern", "git_rev",
                    "jax_backend", "device_count"):
            if key in manifest:
                lines.append(f"  {key:<13} {manifest[key]}")

    phases = payload.get("phases")
    if isinstance(phases, dict) and phases:
        lines.append("phases:")
        for name, secs in phases.items():
            lines.append(f"  {name:<20} {float(secs):>10.3f}s")

    blocks = payload.get("blocks")
    if isinstance(blocks, list) and blocks:
        lines.append("blocks:")
        lines.append(
            "  idx kind            epochs  E_before    E_after     stop"
        )
        for b in blocks:
            lines.append(
                f"  {b.get('index', '?'):>3} {str(b.get('kind', '?')):<15} "
                f"{b.get('epochs_run', '?'):>6}  "
                f"{_fmt_val(b.get('loss_before')):<11} "
                f"{_fmt_val(b.get('loss_after')):<11} "
                f"{b.get('early_stop', '')}"
            )

    trace = payload.get("trace")
    if isinstance(trace, list) and trace:
        lines.append("trace:")
        for root in trace:
            sub: List[str] = []
            _span_lines(root, 1, sub)
            lines.extend(sub)

    events = payload.get("events")
    if isinstance(events, list) and events:
        spans = [e for e in events if e.get("type") == "span"]
        if spans:
            lines.append("spans (event stream, close order):")
            for ev in spans:
                depth = int(ev.get("depth", 0))
                lines.append(
                    f"  {'  ' * depth}{ev.get('name', '?'):<{max(38 - 2 * depth, 8)}}"
                    f"{ev.get('duration_s', 0.0):>10.3f}s"
                    + _fmt_attrs(ev.get("attrs", {}))
                )
        counts: Dict[str, int] = {}
        for ev in events:
            counts[ev.get("type", "?")] = counts.get(ev.get("type", "?"), 0) + 1
        lines.append("events: " + ", ".join(
            f"{n} {t}" for t, n in sorted(counts.items())))

    metrics = payload.get("metrics")
    if isinstance(metrics, dict) and metrics:
        lines.append("metrics:")
        for name in sorted(metrics):
            lines.append(_metric_line(name, metrics[name]))

    return "\n".join(lines) if lines else "(empty artifact)"

"""Run lifecycle: manifest + live tracer/registry + sinks, per process.

``start_run`` swaps the null tracer/metrics singletons for live ones and
records the run manifest (what was run: config, sparsity, method, git
rev, backend). ``Run.finish`` assembles the JSON-summary payload

    {"manifest": ..., "metrics": ..., "trace": ..., **extra}

optionally writes it (``summary_path`` — this is how ``BENCH_ebft.json``
is produced), closes sinks, and restores the null singletons, so runs
never leak state into later code (tests rely on this).

``validate_payload`` is the manifest schema check CI gates artifacts on.
"""
from __future__ import annotations

import dataclasses
import os
import subprocess
import sys
import time
from typing import Any, Dict, List, Optional

from repro.obs import metrics as M
from repro.obs import trace as T
from repro.obs.sinks import ConsoleSink, JsonlSink, write_summary

SCHEMA = "repro.obs/v1"


def git_rev() -> Optional[str]:
    """Short git revision of the working tree, or None outside a repo."""
    try:
        out = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            cwd=os.path.dirname(os.path.abspath(__file__)),
            capture_output=True, text=True, timeout=5,
        )
        return out.stdout.strip() or None
    except Exception:
        return None


def _backend() -> Dict[str, Any]:
    try:
        import jax

        return {"jax_backend": jax.default_backend(),
                "device_count": jax.device_count()}
    except Exception:  # manifest must never fail the run
        return {"jax_backend": "unknown", "device_count": 0}


@dataclasses.dataclass
class Run:
    """One observed run: manifest + live tracer/metrics + sinks."""

    manifest: Dict[str, Any]
    tracer: T.Tracer
    metrics: M.Metrics
    jsonl: Optional[JsonlSink] = None
    console: Optional[ConsoleSink] = None
    _finished: bool = False

    def say(self, line: str) -> None:
        """Human-readable console output (a sink, not a side channel)."""
        if self.console is not None:
            self.console.emit_line(line)

    def payload(self, extra: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
        out: Dict[str, Any] = {
            "manifest": self.manifest,
            "metrics": self.metrics.summary(),
            "trace": self.tracer.tree(),
        }
        if extra:
            out.update(extra)
        return out

    def finish(
        self,
        extra: Optional[Dict[str, Any]] = None,
        summary_path: Optional[str] = None,
    ) -> Dict[str, Any]:
        """Assemble the summary payload, write it, release global state."""
        payload = self.payload(extra)
        if summary_path:
            write_summary(summary_path, payload)
        if not self._finished:
            self._finished = True
            if self.jsonl is not None:
                self.jsonl.close()
            global _CURRENT
            if _CURRENT is self:
                _CURRENT = None
                T.set_tracer(None)
                M.set_registry(None)
        return payload


_CURRENT: Optional[Run] = None


def current_run() -> Optional[Run]:
    return _CURRENT


def start_run(
    name: str,
    *,
    config: Optional[str] = None,
    method: Optional[str] = None,
    sparsity: Optional[float] = None,
    pattern: Optional[str] = None,
    jsonl_path: Optional[str] = None,
    console: bool = True,
    extra_manifest: Optional[Dict[str, Any]] = None,
) -> Run:
    """Begin an observed run; installs live tracer/metrics process-wide.

    A second ``start_run`` while one is active finishes the old run first
    (drivers and benchmarks are sequential; nesting is a bug).
    """
    global _CURRENT
    if _CURRENT is not None:
        _CURRENT.finish()

    manifest: Dict[str, Any] = {
        "schema": SCHEMA,
        "name": name,
        "created_unix": time.time(),
        "argv": list(sys.argv),
        "git_rev": git_rev(),
        **_backend(),
    }
    if config is not None:
        manifest["config"] = config
    if method is not None:
        manifest["method"] = method
    if sparsity is not None:
        manifest["sparsity"] = sparsity
    if pattern:
        manifest["pattern"] = pattern
    if extra_manifest:
        manifest.update(extra_manifest)

    tracer = T.Tracer()
    registry = M.Metrics()
    jsonl = None
    if jsonl_path:
        jsonl = JsonlSink(jsonl_path)
        jsonl.emit({"type": "manifest", "manifest": manifest})
        tracer.add_emitter(jsonl.emit)
        registry.add_emitter(jsonl.emit)

    run = Run(
        manifest=manifest,
        tracer=tracer,
        metrics=registry,
        jsonl=jsonl,
        console=ConsoleSink() if console else None,
    )
    T.set_tracer(tracer)
    M.set_registry(registry)
    _CURRENT = run
    return run


# ---------------------------------------------------------------------------
# artifact schema validation (the CI gate for BENCH_*.json)
# ---------------------------------------------------------------------------
_MANIFEST_FIELDS = {
    "schema": str,
    "name": str,
    "created_unix": (int, float),
    "argv": list,
    "jax_backend": str,
    "device_count": int,
}


def validate_payload(
    payload: Any,
    require: Optional[List[str]] = None,
    max_dispatches_per_block: Optional[int] = None,
    require_cache_hits: bool = False,
) -> List[str]:
    """Returns a list of problems ([] = valid summary artifact).

    ``require`` names additional top-level keys the artifact must carry
    (e.g. ``["blocks", "phases"]`` for ``BENCH_ebft.json``).

    ``max_dispatches_per_block`` gates the fused-walk dispatch budget
    (docs/PERF.md): the artifact's ``dispatch.per_block_max`` — tune-path
    dispatches plus the two stream advances — must not exceed it. CI runs
    the tiny config with ``epochs + 2`` here.

    ``require_cache_hits`` gates a warm autotuner run (docs/PERF.md):
    the artifact's ``kernel_tuning`` section must show every plan
    resolution served from the persistent cache — at least one hit, zero
    misses, zero searches, zero search seconds. CI runs the EBFT job
    once with ``--kernel-tune search`` and asserts this on the second,
    ``--kernel-tune cache`` run.
    """
    problems: List[str] = []
    if not isinstance(payload, dict):
        return [f"artifact is {type(payload).__name__}, expected object"]

    manifest = payload.get("manifest")
    if not isinstance(manifest, dict):
        problems.append("missing 'manifest' object")
    else:
        for field, typ in _MANIFEST_FIELDS.items():
            if field not in manifest:
                problems.append(f"manifest missing {field!r}")
            elif not isinstance(manifest[field], typ):
                problems.append(
                    f"manifest.{field} has type "
                    f"{type(manifest[field]).__name__}"
                )
        if isinstance(manifest.get("schema"), str) \
                and manifest["schema"] != SCHEMA:
            problems.append(
                f"manifest.schema is {manifest['schema']!r}, "
                f"expected {SCHEMA!r}"
            )

    if not isinstance(payload.get("metrics"), dict):
        problems.append("missing 'metrics' object")
    if not isinstance(payload.get("trace"), list):
        problems.append("missing 'trace' span forest")
    for key in require or []:
        if key not in payload:
            problems.append(f"missing required key {key!r}")

    if max_dispatches_per_block is not None:
        dispatch = payload.get("dispatch")
        if not isinstance(dispatch, dict):
            problems.append(
                "missing 'dispatch' object (needed for "
                "--max-dispatches-per-block)"
            )
        else:
            per_block = dispatch.get("per_block_max")
            if not isinstance(per_block, int):
                problems.append(
                    "dispatch.per_block_max missing or non-integer"
                )
            elif per_block > max_dispatches_per_block:
                problems.append(
                    f"dispatch.per_block_max = {per_block} exceeds "
                    f"budget {max_dispatches_per_block}"
                )

    if require_cache_hits:
        tuning = payload.get("kernel_tuning")
        if not isinstance(tuning, dict):
            problems.append(
                "missing 'kernel_tuning' object (needed for "
                "--require-cache-hits)"
            )
        else:
            hits = tuning.get("hits")
            if not isinstance(hits, (int, float)) or hits < 1:
                problems.append(
                    f"kernel_tuning.hits = {hits!r}, expected >= 1 "
                    "(a warm run must resolve at least one plan)"
                )
            for field in ("misses", "searches", "search_s"):
                val = tuning.get(field)
                if not isinstance(val, (int, float)) or val != 0:
                    problems.append(
                        f"kernel_tuning.{field} = {val!r}, expected 0 "
                        "on a warm cache run"
                    )
    return problems

"""repro.obs — tracing, metrics, and profiling for the EBFT pipeline.

The paper's headline claims are operational (one live block, ~30 min
walks, 16 GB peak), so the pipeline needs to be *observable*: this
package provides the three primitives every driver/benchmark uses
instead of ``print()`` + ``time.time()`` (DESIGN.md §8,
docs/OBSERVABILITY.md):

  * :mod:`repro.obs.trace`   — nested wall-time spans with optional
    ``jax.block_until_ready`` fencing, so device work is attributed to
    the span that launched it::

        from repro.obs import trace
        with trace.span("ebft/block", index=i) as sp:
            out = sp.fence(step(...))   # device fence at attribution point

  * :mod:`repro.obs.metrics` — counters / gauges / histograms /
    time-series with a JSON summary and JSONL event stream::

        from repro.obs import metrics
        metrics.counter("serve/tokens").inc(n)
        metrics.gauge("ebft/live_block_bytes").set(b)   # tracks max = peak

  * :mod:`repro.obs.profile` — compile-vs-execute timing for jitted
    steps, analytic FLOPs/bytes accounting for the Pallas kernels
    (roofline model from :mod:`repro.launch.rooflines`), and pytree
    byte/param accounting for the paper's live-block-memory claim.

Everything is **off by default**: the module-level tracer/registry are
null singletons whose methods allocate nothing, so instrumentation in
hot paths is free until :func:`repro.obs.run.start_run` swaps in live
objects. Instrumentation is host-side only — spans and metric updates
must never be traced into jitted code (kernel hooks skip themselves
when they see abstract tracers).

``python -m repro.obs report <artifact>`` renders a run's trace tree
and metric summaries; ``... validate`` checks the manifest schema (the
CI gate for ``BENCH_ebft.json``).
"""
from __future__ import annotations

from repro.obs import metrics, profile, trace  # noqa: F401  (public facades)
from repro.obs.run import Run, current_run, start_run  # noqa: F401


def enabled() -> bool:
    """True when a live run is collecting (the null tracer reports False)."""
    return trace.enabled()

"""Profiling hooks: compile-vs-execute timing, kernel FLOPs/bytes
accounting, and pytree memory accounting.

Three tools (docs/OBSERVABILITY.md §Profiling):

  * :class:`ProfiledFn` wraps a jitted step. The first call for each
    argument signature is split AOT-style (``fn.lower`` timed, then
    ``.compile()`` timed) so compile time is attributed separately from
    execution; every execution is fenced with ``block_until_ready`` and
    recorded as a histogram. When observability is off the wrapper is a
    single branch around the raw function.

  * :func:`record_kernel` times one kernel invocation and books its
    analytic FLOPs/bytes against the roofline hardware model
    (:mod:`repro.launch.rooflines` constants), reporting the ideal time
    alongside the measured one. Callers must skip it while tracing —
    timing a tracer is meaningless and fencing one is an error — via
    :func:`is_abstract`.

  * :func:`live_bytes` / :func:`param_count` / :func:`ebft_live_block_bytes`
    account pytree memory; the EBFT walk uses them to record the
    paper's streaming claim (peak live block = weights + masks + two
    f32 Adam moments) as a measurable gauge.

  * :class:`FirstCallTimer` + :class:`CompileClock` attribute first-call
    (trace+compile) wall time to the region that triggered it without
    fencing — the EBFT walk drains the clock per phase so the
    ``ebft/walk/*_s`` histograms report steady-state and compile cost
    lands in ``ebft/walk/*_compile_s`` (docs/PERF.md).
"""
from __future__ import annotations

import time
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import numpy as np

from repro.launch.rooflines import HBM_BW, PEAK_FLOPS
from repro.obs import metrics as M
from repro.obs import trace as T


def param_count(tree: Any) -> int:
    """Total element count of a pytree (arrays or ShapeDtypeStructs)."""
    return int(sum(int(np.prod(np.shape(x))) for x in jax.tree.leaves(tree)))


def live_bytes(tree: Any) -> int:
    """Total bytes of a pytree's leaves at their stated dtypes."""
    tot = 0
    for x in jax.tree.leaves(tree):
        n = int(np.prod(np.shape(x)))
        tot += n * np.dtype(getattr(x, "dtype", np.float32)).itemsize
    return tot


def ebft_live_block_bytes(block_params: Any, mask_params: Any,
                          n_moments: int = 2) -> int:
    """Live bytes while one block fine-tunes: weights + masks + f32 Adam
    moments — the quantity the paper's 16 GB claim bounds."""
    return (live_bytes(block_params) + live_bytes(mask_params)
            + n_moments * param_count(block_params) * 4)


def is_abstract(*values: Any) -> bool:
    """True when any leaf is a jax tracer (we are inside a jit trace)."""
    for v in values:
        for leaf in jax.tree.leaves(v):
            if isinstance(leaf, jax.core.Tracer):
                return True
    return False


# ---------------------------------------------------------------------------
class DispatchLedger:
    """Counts device dispatches and host-sync events for one region.

    The fused-EBFT acceptance budget (docs/PERF.md) is expressed in these
    two numbers: a *dispatch* is one jitted-executable launch enqueued on
    the device stream; a *host sync* is one device→host transfer the host
    blocks on (``float(x)``, ``np.asarray(x)``, ``device_get``,
    ``block_until_ready``). The ledger is a plain counter pair — always
    live, so :class:`~repro.core.ebft.BlockReport` carries real numbers
    even with observability off — and mirrors into the metrics registry
    when one is installed.

    ``devices`` (a mesh-aware walk passes its device count) additionally
    books every SPMD launch per participating device under
    ``<name>/device_dispatches`` — one host-side dispatch of an SPMD
    executable enqueues work on all ``devices`` chips, and the per-device
    ledger in ``BENCH_ebft.json`` is derived from this counter.
    """

    __slots__ = ("name", "dispatches", "host_syncs", "devices")

    def __init__(self, name: str, devices: int = 1):
        self.name = name
        self.dispatches = 0
        self.host_syncs = 0
        self.devices = max(int(devices), 1)

    @property
    def device_dispatches(self) -> int:
        return self.dispatches * self.devices

    def dispatch(self, n: int = 1) -> None:
        self.dispatches += n
        M.counter(f"{self.name}/dispatches").inc(n)
        M.counter(f"{self.name}/device_dispatches").inc(n * self.devices)

    def host_sync(self, n: int = 1) -> None:
        self.host_syncs += n
        M.counter(f"{self.name}/host_syncs").inc(n)


# ---------------------------------------------------------------------------
def record_kernel(name: str, flops: float, bytes_moved: float,
                  fn: Callable, *args, attrs: Optional[Dict[str, Any]] = None,
                  **kw):
    """Run ``fn(*args, **kw)`` fenced and book it against the roofline.

    Callers guard with ``trace.enabled() and not is_abstract(...)`` so
    the disabled/traced path never reaches here. ``attrs`` (the chosen
    tile plan from repro.kernels.tuning, when one was resolved) opens a
    kernel span carrying them, so traces show which plan each launch ran.
    """
    t0 = time.perf_counter()
    if attrs:
        with T.span(name, **attrs) as sp:
            out = sp.fence(fn(*args, **kw))
    else:
        out = fn(*args, **kw)
        jax.block_until_ready(out)
    dt = time.perf_counter() - t0
    M.histogram(f"{name}/exec_s").observe(dt)
    M.counter(f"{name}/calls").inc()
    M.counter(f"{name}/flops").inc(flops)
    M.counter(f"{name}/bytes").inc(bytes_moved)
    # ideal time on the modeled chip: the larger of the compute and
    # memory terms (same two-term model as launch/rooflines.terms)
    M.gauge(f"{name}/roofline_ideal_s").set(
        max(flops / PEAK_FLOPS, bytes_moved / HBM_BW)
    )
    return out


# ---------------------------------------------------------------------------
class ProfiledFn:
    """Wraps a jitted callable; separates compile time from execution.

    Per argument signature (treedef + leaf shapes/dtypes) the wrapper
    lowers and compiles once, timing each stage; subsequent calls hit
    the cached executable and only record fenced execution time. Falls
    back to plain first-call timing when the callee exposes no ``lower``
    (non-jit callables) or AOT lowering fails.
    """

    def __init__(self, fn: Callable, name: str):
        self.fn = fn
        self.name = name
        self._compiled: Dict[Any, Callable] = {}

    def _sig(self, args: Tuple) -> Any:
        leaves, treedef = jax.tree.flatten(args)
        return treedef, tuple(
            (np.shape(x), str(getattr(x, "dtype", type(x).__name__)))
            for x in leaves
        )

    def __call__(self, *args):
        if not T.enabled():
            return self.fn(*args)
        if is_abstract(*args):  # never profile inside an outer trace
            return self.fn(*args)

        sig = self._sig(args)
        target = self._compiled.get(sig)
        if target is None:
            target = self._compile(sig, args)
        t0 = time.perf_counter()
        out = target(*args)
        jax.block_until_ready(out)
        M.histogram(f"{self.name}/exec_s").observe(time.perf_counter() - t0)
        M.counter(f"{self.name}/calls").inc()
        return out

    def _compile(self, sig: Any, args: Tuple) -> Callable:
        lower = getattr(self.fn, "lower", None)
        target: Optional[Callable] = None
        if lower is not None:
            try:
                t0 = time.perf_counter()
                lowered = lower(*args)
                t_lower = time.perf_counter() - t0
                t0 = time.perf_counter()
                target = lowered.compile()
                t_compile = time.perf_counter() - t0
                M.gauge(f"{self.name}/lower_s").set(t_lower)
                M.gauge(f"{self.name}/compile_s").set(t_compile)
                M.counter(f"{self.name}/compiles").inc()
            except Exception:
                target = None  # AOT unsupported for these args: fall back
        if target is None:
            target = self.fn
            M.counter(f"{self.name}/compile_fallbacks").inc()
        self._compiled[sig] = target
        return target


def profiled(fn: Callable, name: str) -> ProfiledFn:
    """Wrap ``fn`` (ideally ``jax.jit``-ed) with compile/exec profiling."""
    return ProfiledFn(fn, name)


# ---------------------------------------------------------------------------
# first-call (trace+compile) attribution for the walk-phase histograms
# ---------------------------------------------------------------------------
class CompileClock:
    """Accumulates first-call wall time booked by :class:`FirstCallTimer`;
    a consumer (the EBFT walk) ``take()``s the pending total per phase so
    phase histograms can report steady-state and compile separately
    (``ebft/walk/{phase}_s`` vs ``{phase}_compile_s``, docs/PERF.md)."""

    __slots__ = ("_pending",)

    def __init__(self) -> None:
        self._pending = 0.0

    def add(self, dt: float) -> None:
        self._pending += dt

    def take(self) -> float:
        dt, self._pending = self._pending, 0.0
        return dt


_CLOCK = CompileClock()


def compile_clock() -> CompileClock:
    """The process-wide clock the walk drains between phases."""
    return _CLOCK


class FirstCallTimer:
    """Times the *synchronous* part of the first call per argument
    signature and books it on the :class:`CompileClock`.

    jit dispatch is async: a warm call returns as soon as execution is
    enqueued, but the FIRST call for a signature traces and compiles
    synchronously before enqueueing. Timing that call without fencing
    therefore isolates trace+compile from device execution — crucially
    *without* adding a host sync, so wrapping the prefetcher's dispatches
    does not serialize the pipeline it measures. Non-array leaves (e.g. a
    static block index) participate in the signature by value, matching
    jit's own cache keying.
    """

    __slots__ = ("fn", "_seen")

    def __init__(self, fn: Callable):
        self.fn = fn
        self._seen: set = set()

    def _sig(self, args: Tuple, kw: Dict[str, Any]) -> Any:
        leaves, treedef = jax.tree.flatten((args, kw))
        return treedef, tuple(
            (np.shape(x), str(x.dtype)) if hasattr(x, "dtype") else ("val", x)
            for x in leaves
        )

    def __call__(self, *args, **kw):
        if not T.enabled():
            return self.fn(*args, **kw)
        sig = self._sig(args, kw)
        if sig in self._seen:
            return self.fn(*args, **kw)
        self._seen.add(sig)
        t0 = time.perf_counter()
        out = self.fn(*args, **kw)
        _CLOCK.add(time.perf_counter() - t0)
        return out

"""Span tracer: nested wall-time spans with optional device fencing.

A :class:`Span` measures host wall-time between ``__enter__`` and
``__exit__`` on the monotonic clock. Because jax dispatch is async, a
span around ``step(...)`` alone would only time the *launch*; call
``sp.fence(value)`` on the result to ``jax.block_until_ready`` it inside
the span, attributing the device work to the right place.

The module-level :func:`span` dispatches to the current tracer — a
:class:`NullTracer` by default whose ``span()`` returns a stateless
no-op singleton (zero allocation, reentrant), so instrumented hot paths
cost one attribute lookup when observability is off. ``start_run``
(repro.obs.run) installs a live :class:`Tracer`.

Spans must be strictly nested (they form a tree); the tracer keeps the
open-span stack and the list of completed roots. ``Tracer.tree()``
returns the JSON-ready forest the report CLI renders.
"""
from __future__ import annotations

import time
from typing import Any, Callable, Dict, List, Optional


class Span:
    """One timed region. Context manager; re-entry is not supported."""

    __slots__ = ("name", "attrs", "start", "duration", "children", "_tracer")

    def __init__(self, name: str, attrs: Dict[str, Any], tracer: "Tracer"):
        self.name = name
        self.attrs = attrs
        self.start: float = 0.0
        self.duration: float = 0.0
        self.children: List["Span"] = []
        self._tracer = tracer

    def set(self, **attrs) -> "Span":
        """Attach attributes discovered while the span is open."""
        self.attrs.update(attrs)
        return self

    def fence(self, value):
        """Block until ``value``'s device work is done; returns ``value``.

        Puts the async dispatch inside this span's wall-time.
        """
        import jax

        jax.block_until_ready(value)
        return value

    # -- context manager ------------------------------------------------
    def __enter__(self) -> "Span":
        self._tracer._push(self)
        self.start = self._tracer.clock()
        return self

    def __exit__(self, *exc) -> bool:
        self.duration = self._tracer.clock() - self.start
        self._tracer._pop(self)
        return False

    def asdict(self) -> Dict[str, Any]:
        d: Dict[str, Any] = {
            "name": self.name,
            "start": self.start,
            "duration_s": self.duration,
        }
        if self.attrs:
            d["attrs"] = dict(self.attrs)
        if self.children:
            d["children"] = [c.asdict() for c in self.children]
        return d


class _NullSpan:
    """Stateless no-op span — one shared instance, safe to re-enter."""

    __slots__ = ()
    name = ""
    attrs: Dict[str, Any] = {}
    start = 0.0
    duration = 0.0
    children: List = []

    def set(self, **attrs):
        return self

    def fence(self, value):
        return value

    def __enter__(self):
        return self

    def __exit__(self, *exc) -> bool:
        return False


NULL_SPAN = _NullSpan()


class Tracer:
    """Collects a forest of completed spans; emits span-end events."""

    enabled = True

    def __init__(self, clock: Callable[[], float] = time.perf_counter):
        self.clock = clock
        self.roots: List[Span] = []
        self._stack: List[Span] = []
        self._emit: List[Callable[[Dict[str, Any]], None]] = []

    def span(self, name: str, **attrs) -> Span:
        return Span(name, attrs, self)

    def add_emitter(self, fn: Callable[[Dict[str, Any]], None]) -> None:
        """``fn(event_dict)`` is called at every span end (JSONL sinks)."""
        self._emit.append(fn)

    # -- stack maintenance (called by Span) -----------------------------
    def _push(self, sp: Span) -> None:
        if self._stack:
            self._stack[-1].children.append(sp)
        else:
            self.roots.append(sp)
        self._stack.append(sp)

    def _pop(self, sp: Span) -> None:
        # tolerate exceptions unwinding several spans at once: pop to sp
        while self._stack:
            top = self._stack.pop()
            if top is sp:
                break
        if self._emit:
            ev = {
                "type": "span",
                "name": sp.name,
                "start": sp.start,
                "duration_s": sp.duration,
                "depth": len(self._stack),
            }
            if sp.attrs:
                ev["attrs"] = dict(sp.attrs)
            for fn in self._emit:
                fn(ev)

    def tree(self) -> List[Dict[str, Any]]:
        return [r.asdict() for r in self.roots]


class NullTracer:
    """Default tracer: observability off, everything is a no-op."""

    enabled = False
    roots: List[Span] = []

    def span(self, name: str, **attrs) -> _NullSpan:
        return NULL_SPAN

    def add_emitter(self, fn) -> None:
        pass

    def tree(self) -> List[Dict[str, Any]]:
        return []


NULL_TRACER = NullTracer()
_TRACER: Any = NULL_TRACER


def get_tracer():
    return _TRACER


def set_tracer(tracer: Optional[Tracer]) -> None:
    """Install ``tracer`` as the process tracer (None restores the null)."""
    global _TRACER
    _TRACER = tracer if tracer is not None else NULL_TRACER


def span(name: str, **attrs):
    """Open a span on the current tracer (no-op when disabled)."""
    return _TRACER.span(name, **attrs)


def enabled() -> bool:
    return _TRACER.enabled

"""Metrics registry: counters, gauges, histograms, time-series.

Four instrument kinds, matching what the EBFT pipeline needs to report
(docs/OBSERVABILITY.md):

  * ``counter``   — monotone accumulator (tokens served, steps run,
    kernel FLOPs);
  * ``gauge``     — last value plus running min/max, so peaks survive
    the summary (``ebft/live_block_bytes``'s max IS the paper's
    peak-live-memory claim);
  * ``histogram`` — count/sum/min/max plus a bounded sample reservoir
    for percentiles (per-step latencies);
  * ``series``    — (step, value) pairs (loss curves).

Like the tracer, the module-level facade dispatches to the current
registry — a null singleton by default whose instruments are shared
no-op objects, so disabled instrumentation allocates nothing.

``Metrics.summary()`` is the JSON-ready digest embedded in run
artifacts; every update can also be streamed to JSONL emitters.
"""
from __future__ import annotations

import math
from typing import Any, Callable, Dict, List, Optional, Tuple

_RESERVOIR = 4096  # histogram sample cap; scalar stats stay exact beyond it


class Counter:
    __slots__ = ("name", "value", "_emit")
    kind = "counter"

    def __init__(self, name: str, emit=None):
        self.name = name
        self.value = 0.0
        self._emit = emit

    def inc(self, n: float = 1.0) -> None:
        self.value += n
        if self._emit:
            self._emit({"type": "counter", "name": self.name, "inc": n,
                        "value": self.value})

    def summary(self) -> Dict[str, Any]:
        return {"kind": self.kind, "value": self.value}


class Gauge:
    __slots__ = ("name", "last", "min", "max", "n", "_emit")
    kind = "gauge"

    def __init__(self, name: str, emit=None):
        self.name = name
        self.last: Optional[float] = None
        self.min = math.inf
        self.max = -math.inf
        self.n = 0
        self._emit = emit

    def set(self, v: float) -> None:
        v = float(v)
        self.last = v
        self.min = min(self.min, v)
        self.max = max(self.max, v)
        self.n += 1
        if self._emit:
            self._emit({"type": "gauge", "name": self.name, "value": v})

    def summary(self) -> Dict[str, Any]:
        return {"kind": self.kind, "last": self.last, "min": self.min,
                "max": self.max, "n": self.n}


class Histogram:
    __slots__ = ("name", "count", "total", "min", "max", "samples", "_emit")
    kind = "histogram"

    def __init__(self, name: str, emit=None):
        self.name = name
        self.count = 0
        self.total = 0.0
        self.min = math.inf
        self.max = -math.inf
        self.samples: List[float] = []
        self._emit = emit

    def observe(self, v: float) -> None:
        v = float(v)
        self.count += 1
        self.total += v
        self.min = min(self.min, v)
        self.max = max(self.max, v)
        if len(self.samples) < _RESERVOIR:
            self.samples.append(v)
        if self._emit:
            self._emit({"type": "histogram", "name": self.name, "value": v})

    def percentile(self, q: float) -> Optional[float]:
        if not self.samples:
            return None
        s = sorted(self.samples)
        i = min(len(s) - 1, max(0, int(round(q / 100.0 * (len(s) - 1)))))
        return s[i]

    def summary(self) -> Dict[str, Any]:
        return {
            "kind": self.kind, "count": self.count, "sum": self.total,
            "mean": self.total / self.count if self.count else None,
            "min": self.min if self.count else None,
            "max": self.max if self.count else None,
            "p50": self.percentile(50), "p99": self.percentile(99),
        }


class Series:
    __slots__ = ("name", "points", "_emit")
    kind = "series"

    def __init__(self, name: str, emit=None):
        self.name = name
        self.points: List[Tuple[float, float]] = []
        self._emit = emit

    def append(self, value: float, step: Optional[float] = None) -> None:
        step = float(len(self.points) if step is None else step)
        self.points.append((step, float(value)))
        if self._emit:
            self._emit({"type": "series", "name": self.name, "step": step,
                        "value": float(value)})

    def summary(self) -> Dict[str, Any]:
        vals = [v for _, v in self.points]
        return {
            "kind": self.kind, "n": len(vals),
            "first": vals[0] if vals else None,
            "last": vals[-1] if vals else None,
            "min": min(vals) if vals else None,
            "max": max(vals) if vals else None,
            "points": [[s, v] for s, v in self.points],
        }


class Metrics:
    """Live registry: get-or-create instruments by name (kind-checked)."""

    enabled = True

    def __init__(self):
        self._instruments: Dict[str, Any] = {}
        self._emit_fns: List[Callable[[Dict[str, Any]], None]] = []

    def add_emitter(self, fn: Callable[[Dict[str, Any]], None]) -> None:
        self._emit_fns.append(fn)

    def _emit(self, event: Dict[str, Any]) -> None:
        for fn in self._emit_fns:
            fn(event)

    def _get(self, name: str, cls):
        inst = self._instruments.get(name)
        if inst is None:
            inst = cls(name, self._emit if self._emit_fns else None)
            self._instruments[name] = inst
        elif not isinstance(inst, cls):
            raise TypeError(
                f"metric {name!r} already registered as {inst.kind}, "
                f"requested {cls.kind}"
            )
        return inst

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str) -> Histogram:
        return self._get(name, Histogram)

    def series(self, name: str) -> Series:
        return self._get(name, Series)

    def summary(self) -> Dict[str, Dict[str, Any]]:
        return {
            name: inst.summary()
            for name, inst in sorted(self._instruments.items())
        }


class _NullInstrument:
    """Shared no-op instrument (answers every kind's API)."""

    __slots__ = ()
    name = ""
    kind = "null"

    def inc(self, n: float = 1.0) -> None:
        pass

    def set(self, v: float) -> None:
        pass

    def observe(self, v: float) -> None:
        pass

    def append(self, value: float, step: Optional[float] = None) -> None:
        pass

    def summary(self) -> Dict[str, Any]:
        return {}


_NULL_INSTRUMENT = _NullInstrument()


class NullMetrics:
    enabled = False

    def counter(self, name: str) -> _NullInstrument:
        return _NULL_INSTRUMENT

    gauge = histogram = series = counter

    def add_emitter(self, fn) -> None:
        pass

    def summary(self) -> Dict[str, Any]:
        return {}


NULL_METRICS = NullMetrics()
_REGISTRY: Any = NULL_METRICS


def get_registry():
    return _REGISTRY


def set_registry(registry: Optional[Metrics]) -> None:
    """Install ``registry`` as the process registry (None restores null)."""
    global _REGISTRY
    _REGISTRY = registry if registry is not None else NULL_METRICS


def counter(name: str):
    return _REGISTRY.counter(name)


def gauge(name: str):
    return _REGISTRY.gauge(name)


def histogram(name: str):
    return _REGISTRY.histogram(name)


def series(name: str):
    return _REGISTRY.series(name)


def summary() -> Dict[str, Any]:
    return _REGISTRY.summary()


def enabled() -> bool:
    return _REGISTRY.enabled

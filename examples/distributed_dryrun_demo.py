"""Distribution demo on forced CPU devices: shard a model over a (2, 4)
mesh, run a real sharded train step, checkpoint, then *elastically*
restore onto a (4, 2) mesh — the shrink/regrow path a 1000-node job needs
when a pod drops.

    PYTHONPATH=src python examples/distributed_dryrun_demo.py

(This example owns its process so it may force 8 host devices — tests and
other examples keep the default 1.)
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import ckpt as CK
from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.distributed import sharding as SH
from repro.launch import steps as ST
from repro.optim.optimizers import adamw


def train_on_mesh(mesh, steps, ck_dir, start=0):
    cfg = get_config("tiny_dense").replace(num_layers=2)
    shape = ShapeConfig("demo", 64, 8, "train")
    cell = ST.build_train_cell(cfg, shape, mesh, microbatches=2, fsdp=False)
    # init from the cell's ADAPTED config (production numerics: bf16)
    params_host = cell.model.init(jax.random.PRNGKey(0))
    jitted = jax.jit(cell.fn, in_shardings=cell.in_shardings,
                     out_shardings=cell.out_shardings,
                     donate_argnums=cell.donate_argnums)
    opt = adamw(1e-4)
    with mesh:
        params = jax.device_put(params_host, cell.in_shardings[0])
        opt_state = jax.device_put(opt.init(params_host), cell.in_shardings[1])
        if start:
            tree = CK.restore(ck_dir, {"params": params, "opt_state": opt_state},
                              shardings={"params": cell.in_shardings[0],
                                         "opt_state": cell.in_shardings[1]})
            params, opt_state = tree["params"], tree["opt_state"]
        loss = None
        for s in range(start, start + steps):
            batch = jax.device_put(
                {"tokens": jnp.asarray(
                    np.random.default_rng(s).integers(0, 512, (8, 64), np.int32))},
                cell.in_shardings[2])
            params, opt_state, metrics = jitted(params, opt_state, batch)
            loss = float(metrics["loss"])
        CK.save(ck_dir, {"params": jax.device_get(params),
                         "opt_state": jax.device_get(opt_state)},
                step=start + steps, mesh_shape=tuple(dict(mesh.shape).values()))
    return loss


def main() -> None:
    print(f"devices: {jax.device_count()}")
    ck = "/tmp/repro_elastic_demo"
    import shutil
    shutil.rmtree(ck, ignore_errors=True)

    mesh_a = jax.make_mesh((2, 4), ("data", "model"))
    loss_a = train_on_mesh(mesh_a, steps=4, ck_dir=ck)
    print(f"mesh (2,4): 4 steps, loss {loss_a:.3f}; checkpointed")

    # 'a pod dropped': resume the SAME checkpoint on a (4,2) mesh
    mesh_b = jax.make_mesh((4, 2), ("data", "model"))
    loss_b = train_on_mesh(mesh_b, steps=4, ck_dir=ck, start=4)
    print(f"mesh (4,2): resumed step 4 -> 8, loss {loss_b:.3f} "
          f"(elastic reshard-on-restore)")


if __name__ == "__main__":
    main()

"""Quickstart: the paper's pipeline end-to-end in ~3 minutes on CPU.

    PYTHONPATH=src python examples/quickstart.py

1. pretrain a tiny LM on the synthetic corpus,
2. prune it to 70% sparsity with Wanda,
3. EBFT block-wise fine-tuning (Alg. 1),
4. compare held-out perplexity: dense vs pruned vs EBFT.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import ebft
from repro.core.evaluate import perplexity
from repro.core.masks import prune
from repro.data.tokens import (
    CorpusConfig, SyntheticCorpus, calibration_set, corpus_iterator, eval_set,
)
from repro.models.model import build
from repro.optim.optimizers import adamw
from repro.training.train_loop import make_train_step


def main() -> None:
    cfg = get_config("tiny_dense")
    model = build(cfg)
    corpus = SyntheticCorpus(CorpusConfig(vocab_size=cfg.vocab_size))

    # 1. pretrain the dense teacher
    params = model.init(jax.random.PRNGKey(0))
    opt = adamw(3e-3)
    step = jax.jit(make_train_step(model.loss, opt))
    opt_state = opt.init(params)
    it = corpus_iterator(corpus, batch=32, seq_len=128, seed=1)
    print("pretraining 200 steps...")
    for i in range(200):
        params, opt_state, metrics, _ = step(
            params, opt_state, {"tokens": jnp.asarray(next(it))}, None
        )
    print(f"  final loss {float(metrics['loss']):.3f}")

    ev = eval_set(corpus, 16, 128)
    ppl_dense = perplexity(model, params, ev)

    # 2. prune (the paper: masks can come from ANY method)
    calib = calibration_set(corpus, 64, 128)  # the paper's D_c, miniature
    masks, pruned = prune(model, params, calib, method="wanda", sparsity=0.7)
    ppl_pruned = perplexity(model, pruned, ev)

    # 3. EBFT: block-wise reconstruction fine-tuning (Alg. 1)
    tuned, reports = ebft.finetune(
        model, params, pruned, masks, calib,
        ebft.EBFTConfig(lr=1e-2, epochs=8, microbatch=8),
        log=print,
    )
    ppl_ebft = perplexity(model, tuned, ev)

    # 4. the paper's ordering: dense < EBFT < pruned
    print(f"\nwikitext2-stand-in perplexity @70% sparsity")
    print(f"  dense   {ppl_dense:8.2f}")
    print(f"  wanda   {ppl_pruned:8.2f}")
    print(f"  +EBFT   {ppl_ebft:8.2f}   "
          f"(recovered {100*(ppl_pruned-ppl_ebft)/(ppl_pruned-ppl_dense):.0f}% "
          f"of the pruning damage)")


if __name__ == "__main__":
    main()

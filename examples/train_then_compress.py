"""End-to-end driver: train a ~small LM for a few hundred steps, then run
the full compression pipeline (prune -> EBFT -> N:M re-pack -> serve) —
the lifecycle a production team would run.

    PYTHONPATH=src python examples/train_then_compress.py [--steps 300]

Uses the checkpointed Trainer (fault-tolerant: re-running resumes), then
2:4-prunes, EBFT-fine-tunes, compresses to the nm_spmm kernel layout,
verifies the compressed forward matches, and serves a batch of requests
with the sparse weights.
"""
from __future__ import annotations

import argparse
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import ckpt as CK
from repro.configs import get_config
from repro.core import ebft
from repro.core.evaluate import perplexity
from repro.core.masks import prune
from repro.data.tokens import (
    CorpusConfig, SyntheticCorpus, calibration_set, eval_set,
)
from repro.models.model import build
from repro.optim.optimizers import adamw
from repro.optim.schedules import warmup_cosine
from repro.serving.decode import Request, Server
from repro.sparsity.sparse_params import (
    map_prunable, nm_compress, nm_decompress, to_matrix_stacked,
)
from repro.training.train_loop import Trainer, make_train_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--ckpt-dir", default=os.path.join(tempfile.gettempdir(), "repro_e2e_ck"))
    args = ap.parse_args()

    cfg = get_config("tiny_dense")
    model = build(cfg)
    corpus = SyntheticCorpus(CorpusConfig(vocab_size=cfg.vocab_size))

    # ---- train (checkpointed; rerun to resume) -------------------------
    params = model.init(jax.random.PRNGKey(0))
    opt = adamw(warmup_cosine(3e-3, warmup=20, total=args.steps))
    opt_state = opt.init(params)
    step = jax.jit(make_train_step(model.loss, opt))

    def data_fn(s: int):
        r = np.random.default_rng(7000 + s)
        return {"tokens": jnp.asarray(
            np.stack([corpus.sample(r, 128) for _ in range(32)])
        )}

    start = CK.latest_step(args.ckpt_dir) or 0
    if start:
        tree = CK.restore(args.ckpt_dir, {"params": params, "opt_state": opt_state})
        params, opt_state = tree["params"], tree["opt_state"]
        print(f"resumed from step {start}")
    trainer = Trainer(step_fn=step, data_fn=data_fn, ckpt_dir=args.ckpt_dir,
                      ckpt_every=100, log_every=50)
    params, opt_state, hist = trainer.run(params, opt_state, start,
                                          max(args.steps - start, 0))
    CK.wait_all()
    for s, l in hist:
        print(f"  step {s:4d} loss {l:.3f}")

    ev = eval_set(corpus, 16, 128)
    print(f"dense ppl {perplexity(model, params, ev):.2f}")

    # ---- compress: 2:4 prune + EBFT ------------------------------------
    calib = calibration_set(corpus, 64, 128)
    masks, pruned = prune(model, params, calib, method="wanda",
                          sparsity=0.5, pattern=(2, 4))
    print(f"2:4 pruned ppl {perplexity(model, pruned, ev):.2f}")
    tuned, _ = ebft.finetune(model, params, pruned, masks, calib,
                             ebft.EBFTConfig(lr=1e-2, epochs=8))
    print(f"+EBFT ppl {perplexity(model, tuned, ev):.2f}")

    # ---- re-pack to the nm_spmm kernel layout and verify ----------------
    packed_bytes = [0]
    dense_bytes = [0]

    def pack(name, leaf):
        mat, _ = to_matrix_stacked(name, leaf)  # (stack..., R, O)
        R, O = mat.shape[-2:]
        if R % 4 or name == "conv_w":
            return leaf
        m3 = mat.reshape(-1, R, O)
        mask = (m3 != 0).astype(jnp.float32)
        # exact 2:4 leaves only (others keep dense layout)
        g = mask.reshape(m3.shape[0], R // 4, 4, O).sum(axis=2)
        if not bool(jnp.all(g == 2)):
            return leaf
        vals, idx = jax.vmap(lambda w, m: nm_compress(w, m, 2, 4))(m3, mask)
        packed_bytes[0] += vals.size * vals.dtype.itemsize + idx.size // 4
        dense_bytes[0] += m3.size * m3.dtype.itemsize
        back = jax.vmap(lambda v, i: nm_decompress(v, i, 2, 4))(vals, idx)
        assert bool(jnp.all(back == m3)), "N:M pack/unpack mismatch"
        return leaf

    map_prunable(pack, tuned)
    if dense_bytes[0]:
        print(f"nm-packed prunable weights: {dense_bytes[0]/2**20:.1f} MiB -> "
              f"{packed_bytes[0]/2**20:.1f} MiB "
              f"({dense_bytes[0]/max(packed_bytes[0],1):.2f}x HBM saving for the "
              f"nm_spmm kernel)")

    # ---- serve the sparse model ----------------------------------------
    rng = np.random.default_rng(0)
    reqs = [Request(uid=i, prompt=corpus.sample(rng, 24), max_new=8)
            for i in range(6)]
    results = Server(model, tuned, batch_size=3, max_len=64).serve(reqs)
    print(f"served {len(results)} requests with the EBFT-sparse weights")


if __name__ == "__main__":
    main()

"""EBFT across model families — the paper's technique is block-structural,
so the same driver fine-tunes a dense transformer, an MoE, and a Mamba2
SSM (DESIGN.md §5 applicability table).

    PYTHONPATH=src python examples/multiarch_ebft.py [--archs tiny_dense,tiny_moe,tiny_ssm]
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import ebft
from repro.core.evaluate import perplexity
from repro.core.masks import prune
from repro.data.tokens import (
    CorpusConfig, SyntheticCorpus, calibration_set, corpus_iterator, eval_set,
)
from repro.models.model import build
from repro.optim.optimizers import adamw
from repro.training.train_loop import make_train_step


def run_one(arch: str, sparsity: float) -> None:
    cfg = get_config(arch)
    model = build(cfg)
    corpus = SyntheticCorpus(CorpusConfig(vocab_size=cfg.vocab_size))
    params = model.init(jax.random.PRNGKey(0))

    # SSD dynamics (dt, A_log) are lr-sensitive: train SSM/hybrid cooler
    opt = adamw(1e-3 if cfg.family in ("ssm", "hybrid") else 3e-3)
    step = jax.jit(make_train_step(model.loss, opt))
    opt_state = opt.init(params)
    it = corpus_iterator(corpus, batch=16, seq_len=128, seed=1)
    for _ in range(120):
        params, opt_state, _, _ = step(
            params, opt_state, {"tokens": jnp.asarray(next(it))}, None
        )

    calib = calibration_set(corpus, 32, 128)
    ev = eval_set(corpus, 8, 128)
    ppl_dense = perplexity(model, params, ev)
    masks, pruned = prune(model, params, calib, method="wanda", sparsity=sparsity)
    ppl_pruned = perplexity(model, pruned, ev)
    t0 = time.time()
    tuned, reports = ebft.finetune(model, params, pruned, masks, calib,
                                   ebft.EBFTConfig(lr=1e-2, epochs=6))
    ppl = perplexity(model, tuned, ev)
    print(f"{arch:12s} [{cfg.family:6s}] blocks={model.num_blocks:2d} "
          f"dense={ppl_dense:7.2f} pruned={ppl_pruned:7.2f} ebft={ppl:7.2f} "
          f"({time.time()-t0:.0f}s)")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--archs", default="tiny_dense,tiny_moe,tiny_ssm")
    ap.add_argument("--sparsity", type=float, default=0.6)
    args = ap.parse_args()
    print(f"EBFT across families at {args.sparsity:.0%} sparsity")
    for arch in args.archs.split(","):
        run_one(arch, args.sparsity)


if __name__ == "__main__":
    main()

"""Sharding rules on the production 16x16 / 2x16x16 meshes — AbstractMesh
lets us verify every rule without 256 devices (assignment note: tests see
1 real device; only dryrun forces 512)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config, list_configs
from repro.distributed import sharding as SH
from repro.launch.mesh import make_abstract_mesh
from repro.models.model import build

MESH1 = make_abstract_mesh((16, 16), ("data", "model"))
MESH2 = make_abstract_mesh((2, 16, 16), ("pod", "data", "model"))


def _shapes_tree(arch):
    cfg = get_config(arch)
    model = build(cfg)
    return cfg, jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))


def _assert_divisible(tree, specs, mesh):
    def g(path, leaf, spec):
        for d, ax in enumerate(spec):
            if ax is None:
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            size = 1
            for a in axes:
                size *= SH.mesh_axis_size(mesh, a)
            assert leaf.shape[d] % size == 0, (
                f"{'/'.join(str(p) for p in path)} dim {d} = {leaf.shape[d]} "
                f"not divisible by {size}"
            )
        return leaf

    jax.tree_util.tree_map_with_path(
        lambda p, l, s: g(p, l, s), tree, specs
    )


@pytest.mark.parametrize("arch", list_configs())
@pytest.mark.parametrize("mesh", [MESH1, MESH2], ids=["single", "multi"])
def test_param_pspecs_always_divisible(arch, mesh):
    """The #1 dry-run contract: every emitted spec divides its dim."""
    cfg, shapes = _shapes_tree(arch)
    specs = SH.param_pspecs(shapes, mesh, fsdp=False)
    _assert_divisible(shapes, specs, mesh)


@pytest.mark.parametrize("arch", ["qwen1_5_110b", "kimi_k2_1t_a32b"])
def test_param_pspecs_fsdp_divisible_and_shards_more(arch):
    cfg, shapes = _shapes_tree(arch)
    base = SH.param_pspecs(shapes, MESH1, fsdp=False)
    fsdp = SH.param_pspecs(shapes, MESH1, fsdp=True)
    _assert_divisible(shapes, fsdp, MESH1)
    n_base = sum(
        1 for s in jax.tree.leaves(base, is_leaf=lambda x: isinstance(x, P))
        if any(a is not None for a in s)
    )
    n_fsdp = sum(
        1 for s in jax.tree.leaves(fsdp, is_leaf=lambda x: isinstance(x, P))
        if any(a is not None for a in s)
    )
    assert n_fsdp > n_base


def test_attention_never_shards_head_dim():
    """The scores einsum contracts hd: sharding it causes a full-scores
    all-reduce per attention chunk (the bug this rule guards against)."""
    for arch in list_configs():
        cfg, shapes = _shapes_tree(arch)
        specs = SH.param_pspecs(shapes, MESH1, fsdp=False)

        def g(path, leaf, spec):
            names = SH._path_names(path)
            if names[-1] in ("wq", "wk", "wv"):
                # layout (L, d, H, hd): hd is the LAST dim
                assert spec[-1] is None, f"{arch} {names}: hd sharded {spec}"
            return leaf

        jax.tree_util.tree_map_with_path(g, shapes, specs)


def test_moe_experts_shard_over_model_axis():
    cfg, shapes = _shapes_tree("deepseek_moe_16b")
    specs = SH.param_pspecs(shapes, MESH1, fsdp=False)

    found = []

    def g(path, leaf, spec):
        names = SH._path_names(path)
        if "experts" in names and names[-1] in ("w_up", "w_gate", "w_down"):
            e_dim = leaf.ndim - 3
            found.append(spec[e_dim] == "model")
        return leaf

    jax.tree_util.tree_map_with_path(g, shapes, specs)
    assert found and all(found)


def test_router_replicated():
    cfg, shapes = _shapes_tree("kimi_k2_1t_a32b")
    specs = SH.param_pspecs(shapes, MESH1, fsdp=False)

    def g(path, leaf, spec):
        names = SH._path_names(path)
        if "router" in names:
            assert all(a is None for a in spec), f"router sharded: {spec}"
        return leaf

    jax.tree_util.tree_map_with_path(g, shapes, specs)


def test_vocab_sharded_embed_and_head():
    cfg, shapes = _shapes_tree("qwen1_5_4b")
    specs = SH.param_pspecs(shapes, MESH1, fsdp=False)
    assert specs["embed"]["tok"][0] == "model"
    assert specs["head"]["w"][1] == "model"


def test_batch_pspecs_single_and_multi_pod():
    batch = {"tokens": jax.ShapeDtypeStruct((256, 128), jnp.int32)}
    s1 = SH.batch_pspecs(batch, MESH1)
    assert s1["tokens"][0] in ("data", ("data",))  # P normalizes 1-tuples
    s2 = SH.batch_pspecs(batch, MESH2)
    assert s2["tokens"][0] == ("pod", "data")
    # non-divisible batch (long_500k B=1) falls back to replication
    b1 = {"tokens": jax.ShapeDtypeStruct((1, 128), jnp.int32)}
    s3 = SH.batch_pspecs(b1, MESH1)
    assert all(a is None for a in s3["tokens"])


def test_opt_pspecs_zero1_adds_data_axis():
    from repro.optim.optimizers import adamw

    cfg, shapes = _shapes_tree("qwen1_5_110b")
    opt = adamw(1e-4)
    opt_shapes = jax.eval_shape(opt.init, shapes)
    pspecs = SH.param_pspecs(shapes, MESH1, fsdp=False)
    ospecs = SH.opt_pspecs(opt_shapes, pspecs, MESH1)
    _assert_divisible(opt_shapes, ospecs, MESH1)
    # at least one moment leaf picked up the data axis (ZeRO-1)
    has_data = any(
        "data" in [a for a in spec if a is not None]
        for spec in jax.tree.leaves(ospecs, is_leaf=lambda x: isinstance(x, P))
    )
    assert has_data


def test_cache_pspecs_divisible():
    for arch in ("qwen1_5_110b", "zamba2_1_2b", "mamba2_130m"):
        cfg = get_config(arch)
        model = build(cfg)
        state = jax.eval_shape(lambda m=model: m.init_serve_state(128, 1024))
        specs = SH.cache_pspecs(state, MESH1)
        _assert_divisible(state, specs, MESH1)

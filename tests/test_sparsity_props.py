"""Property-based tests (hypothesis) on the sparsity-layer invariants."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.sparsity import sparse_params as SP

SET = settings(max_examples=25, deadline=None, derandomize=True)


@st.composite
def matrices(draw, max_r=16, max_o=12):
    r = draw(st.integers(2, max_r))
    o = draw(st.integers(1, max_o))
    seed = draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=(r, o)).astype(np.float32))


@st.composite
def nm_matrices(draw):
    m = draw(st.sampled_from([2, 4, 8]))
    n = draw(st.integers(1, m - 1))
    groups = draw(st.integers(1, 8))
    o = draw(st.integers(1, 12))
    seed = draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.normal(size=(groups * m, o)).astype(np.float32))
    return w, n, m


# ---------------------------------------------------------------------------
@SET
@given(matrices(), st.floats(0.0, 0.95))
def test_topk_mask_rows_sparsity(scores, sparsity):
    mask = SP.topk_mask_rows(scores, sparsity)
    R = scores.shape[0]
    keep = max(1, int(round(R * (1.0 - sparsity))))
    per_col = np.asarray(mask).sum(axis=0)
    assert np.all(per_col == keep)


@SET
@given(matrices(), st.floats(0.0, 0.95))
def test_global_topk_keeps_highest(scores, sparsity):
    mask = np.asarray(SP.global_topk_mask(scores, sparsity))
    s = np.asarray(scores)
    if mask.min() == 1.0:
        return
    kept_min = s[mask == 1].min()
    dropped_max = s[mask == 0].max()
    assert kept_min >= dropped_max


@SET
@given(nm_matrices())
def test_nm_mask_exact_group_counts(wm):
    w, n, m = wm
    mask = np.asarray(SP.nm_mask(w, n, m))
    R, O = mask.shape
    groups = mask.reshape(R // m, m, O).sum(axis=1)
    assert np.all(groups == n)


@SET
@given(nm_matrices())
def test_nm_compress_decompress_roundtrip(wm):
    w, n, m = wm
    mask = SP.nm_mask(w, n, m)
    vals, idx = SP.nm_compress(w * mask, mask, n, m)
    back = SP.nm_decompress(vals, idx, n, m)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(w * mask))
    # idx must address within groups
    assert np.asarray(idx).min() >= 0 and np.asarray(idx).max() < m


@SET
@given(nm_matrices())
def test_nm_mask_keeps_largest_per_group(wm):
    w, n, m = wm
    scores = jnp.abs(w)
    mask = np.asarray(SP.nm_mask(scores, n, m))
    s = np.asarray(scores)
    R, O = s.shape
    sg = s.reshape(R // m, m, O)
    mg = mask.reshape(R // m, m, O)
    for g in range(R // m):
        for o in range(O):
            kept = sg[g, mg[g, :, o] == 1, o]
            dropped = sg[g, mg[g, :, o] == 0, o]
            if len(dropped):
                assert kept.min() >= dropped.max() - 1e-6


# ---------------------------------------------------------------------------
@SET
@given(st.integers(0, 2**31 - 1), st.floats(0.1, 0.9))
def test_apply_masks_idempotent_and_grad_mask_consistent(seed, sparsity):
    rng = np.random.default_rng(seed)
    params = {
        "blocks": {
            "attn": {"wq": jnp.asarray(rng.normal(size=(8, 4, 2)).astype(np.float32))},
            "mlp": {"w_up": jnp.asarray(rng.normal(size=(8, 16)).astype(np.float32))},
            "ln": {"w": jnp.ones((8,), jnp.float32)},
        }
    }
    masks = jax.tree_util.tree_map_with_path(
        lambda path, p: (
            SP.from_matrix(
                SP.topk_mask_rows(jnp.abs(SP.to_matrix(SP._path_names(path)[-1], p)[0]), sparsity),
                SP.to_matrix(SP._path_names(path)[-1], p)[1],
            )
            if SP.is_prunable(path, p)
            else jnp.ones((), jnp.float32)
        ),
        params,
    )
    once = SP.apply_masks(params, masks)
    twice = SP.apply_masks(once, masks)
    for a, b in zip(jax.tree.leaves(once), jax.tree.leaves(twice)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # gradient masking zeroes exactly the pruned slots
    grads = jax.tree.map(jnp.ones_like, params)
    mg = SP.mask_gradients(grads, masks)
    wq = np.asarray(mg["blocks"]["attn"]["wq"])
    mk = np.asarray(masks["blocks"]["attn"]["wq"])
    assert np.all(wq[mk == 0] == 0) and np.all(wq[mk == 1] == 1)


def test_to_from_matrix_roundtrip_all_names():
    rng = np.random.default_rng(0)
    shapes = {
        "wq": (6, 4, 2), "wk": (6, 2, 2), "wv": (6, 2, 2), "wo": (4, 2, 6),
        "w_up": (6, 8), "w_gate": (6, 8), "w_down": (8, 6),
        "in_z": (6, 2, 3), "in_x": (6, 2, 3), "in_B": (6, 4), "in_C": (6, 4),
        "in_dt": (6, 2), "out": (2, 3, 6), "conv_w": (4, 10),
    }
    for name, shape in shapes.items():
        leaf = jnp.asarray(rng.normal(size=shape).astype(np.float32))
        mat, tag = SP.to_matrix(name, leaf)
        assert mat.ndim == 2
        back = SP.from_matrix(mat, tag)
        np.testing.assert_array_equal(np.asarray(back), np.asarray(leaf))


def test_expert_batched_view():
    leaf = jnp.zeros((5, 6, 7))  # (E, d, ff)
    mat, tag = SP.to_matrix("w_up", leaf)
    assert mat.shape == (5, 6, 7) and tag[0] == "expert"


def test_is_prunable_respects_protected_parents():
    import jax.tree_util as jtu

    tree = {
        "embed": {"tok": jnp.zeros((10, 4))},
        "router": {"w": jnp.zeros((4, 8))},
        "attn": {"wq": jnp.zeros((4, 2, 2))},
        "head": {"w": jnp.zeros((4, 10))},
    }
    flags = {}

    def g(path, leaf):
        flags["/".join(SP._path_names(path))] = SP.is_prunable(path, leaf)
        return leaf

    jtu.tree_map_with_path(g, tree)
    assert flags["attn/wq"]
    assert not flags["embed/tok"]
    assert not flags["router/w"]
    assert not flags["head/w"]

"""The static HLO analyzer that powers the roofline (launch/hlo_analysis):
exactness on compiled programs + parser unit tests on HLO text fixtures."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch import hlo_analysis as HA


def test_scan_trip_count_weighting_exact():
    """cost_analysis counts scan bodies once; the analyzer must multiply
    by the trip count exactly."""
    def f(x, w):
        def body(h, _):
            return jnp.tanh(h @ w), None
        h, _ = jax.lax.scan(body, x, None, length=10)
        return h

    x = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    w = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    c = jax.jit(f).lower(x, w).compile()
    st = HA.analyze(c.as_text(), 1)
    assert st.dot_flops == pytest.approx(10 * 2 * 128 * 256 * 256, rel=1e-6)


def test_nested_scan_multiplies():
    def f(x, w):
        def outer(h, _):
            def inner(h2, _):
                return jnp.tanh(h2 @ w), None
            h2, _ = jax.lax.scan(inner, h, None, length=3)
            return h2, None
        h, _ = jax.lax.scan(outer, x, None, length=5)
        return h

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    w = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    c = jax.jit(f).lower(x, w).compile()
    st = HA.analyze(c.as_text(), 1)
    assert st.dot_flops == pytest.approx(15 * 2 * 64 * 64 * 64, rel=1e-6)


def test_grad_of_scan_counts_fwd_and_bwd():
    def loss(x, w):
        def body(h, _):
            return jnp.tanh(h @ w), None
        h, _ = jax.lax.scan(body, x, None, length=4)
        return jnp.sum(h)

    x = jax.ShapeDtypeStruct((32, 32), jnp.float32)
    w = jax.ShapeDtypeStruct((32, 32), jnp.float32)
    c = jax.jit(jax.grad(loss, argnums=1)).lower(x, w).compile()
    st = HA.analyze(c.as_text(), 1)
    fwd = 4 * 2 * 32 * 32 * 32
    # backward adds ~2x the forward dots (dL/dh and dL/dw per step)
    assert st.dot_flops >= 2.5 * fwd


def test_shape_bytes_tuple_and_layout():
    assert HA.shape_bytes("f32[4,8]{1,0}") == 128
    assert HA.shape_bytes("bf16[10]") == 20
    assert HA.shape_bytes("(f32[2,2]{1,0}, s32[3])") == 28
    assert HA.shape_bytes("pred[7]") == 7
    assert HA.shape_bytes("f32[]") == 4


FIXTURE = """HloModule test

%add.clone (x: f32[], y: f32[]) -> f32[] {
  %x = f32[] parameter(0)
  %y = f32[] parameter(1)
  ROOT %a = f32[] add(%x, %y)
}

ENTRY %main (p0: f32[16,64]) -> f32[16,64] {
  %p0 = f32[16,64]{1,0} parameter(0)
  %ar = f32[16,64]{1,0} all-reduce(%p0), channel_id=1, replica_groups=[4,8]<=[32], to_apply=%add.clone
  %ag = f32[16,64]{1,0} all-gather(%ar), channel_id=2, replica_groups={{0,1,2,3}}, dimensions={0}
  ROOT %cp = f32[16,64]{1,0} collective-permute(%ag), channel_id=3, source_target_pairs={{0,1},{1,0}}
}
"""


def test_collective_parsing_from_fixture():
    st = HA.analyze(FIXTURE, 32)
    size = 16 * 64 * 4
    # all-reduce over groups of 8: 2*(7/8)*size
    # all-gather groups of 4: (3/4)*size ; permute: size
    expect = int(2 * (7 / 8) * size) + int((3 / 4) * size) + size
    assert st.collective_wire == pytest.approx(expect)
    assert st.by_collective["all-reduce"] == pytest.approx(int(2 * (7 / 8) * size))
    assert set(st.by_group_size) == {8, 4, 32}


def test_group_size_formats():
    ins = HA.Instruction("x", "f32[4]", "all-reduce", [],
                         "replica_groups=[16,16]<=[256]")
    assert HA.group_size(ins, 256) == 16
    ins2 = HA.Instruction("x", "f32[4]", "all-reduce", [],
                          "replica_groups={{0,1,2},{3,4,5}}")
    assert HA.group_size(ins2, 256) == 3
    ins3 = HA.Instruction("x", "f32[4]", "all-reduce", [], "no groups")
    assert HA.group_size(ins3, 256) == 256


# ---------------------------------------------------------------------------
# while-loop trip-count recovery (computation_multipliers)
# ---------------------------------------------------------------------------
NESTED_WHILE = """HloModule nested_loops

%inner_cond (p0: (s32[], f32[8])) -> pred[] {
  %p0 = (s32[], f32[8]) parameter(0)
  %i = s32[] get-tuple-element(%p0), index=0
  %three = s32[] constant(3)
  ROOT %lt = pred[] compare(%i, %three), direction=LT
}

%inner_body (p1: (s32[], f32[8])) -> (s32[], f32[8]) {
  %p1 = (s32[], f32[8]) parameter(0)
  %j = s32[] get-tuple-element(%p1), index=0
  %x = f32[8]{0} get-tuple-element(%p1), index=1
  %one = s32[] constant(1)
  %jp = s32[] add(%j, %one)
  %y = f32[8]{0} add(%x, %x)
  ROOT %t = (s32[], f32[8]) tuple(%jp, %y)
}

%outer_cond (p3: (s32[], f32[8])) -> pred[] {
  %p3 = (s32[], f32[8]) parameter(0)
  ROOT %true = pred[] constant(1)
}

%outer_body (p2: (s32[], f32[8])) -> (s32[], f32[8]) {
  %p2 = (s32[], f32[8]) parameter(0)
  ROOT %w_in = (s32[], f32[8]) while(%p2), condition=%inner_cond, body=%inner_body
}

ENTRY %main (arg: (s32[], f32[8])) -> (s32[], f32[8]) {
  %arg = (s32[], f32[8]) parameter(0)
  ROOT %w_out = (s32[], f32[8]) while(%arg), condition=%outer_cond, body=%outer_body, backend_config={"known_trip_count":{"n":"5"}}
}
"""


def test_nested_while_multipliers_multiply():
    """Outer trip 5 (known_trip_count backend_config) x inner trip 3
    (compare-with-constant fallback) -> the inner body runs 15 times."""
    comps = HA.parse_module(NESTED_WHILE)
    mult = HA.computation_multipliers(comps)
    assert mult["main"] == 1.0
    assert mult["outer_body"] == 5.0
    assert mult["inner_body"] == 15.0
    assert mult["inner_cond"] == 15.0


def _single_while(cond_lines: str) -> str:
    return f"""HloModule one_loop

%cond (pc: (s32[], f32[4])) -> pred[] {{
  %pc = (s32[], f32[4]) parameter(0)
{cond_lines}
}}

%body (pb: (s32[], f32[4])) -> (s32[], f32[4]) {{
  %pb = (s32[], f32[4]) parameter(0)
  ROOT %same = (s32[], f32[4]) copy(%pb)
}}

ENTRY %main (a: (s32[], f32[4])) -> (s32[], f32[4]) {{
  %a = (s32[], f32[4]) parameter(0)
  ROOT %w = (s32[], f32[4]) while(%a), condition=%cond, body=%body
}}
"""


def test_trip_count_compare_with_constant_fallback():
    text = _single_while(
        "  %i = s32[] get-tuple-element(%pc), index=0\n"
        "  %seven = s32[] constant(7)\n"
        "  ROOT %lt = pred[] compare(%i, %seven), direction=LT"
    )
    mult = HA.computation_multipliers(HA.parse_module(text))
    assert mult["body"] == 7.0


def test_trip_count_known_trip_count_wins_over_condition():
    """When backend_config carries known_trip_count, the condition's
    constants must be ignored (XLA's count is authoritative)."""
    text = _single_while(
        "  %i = s32[] get-tuple-element(%pc), index=0\n"
        "  %seven = s32[] constant(7)\n"
        "  ROOT %lt = pred[] compare(%i, %seven), direction=LT"
    ).replace(
        "condition=%cond, body=%body",
        'condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"11"}}',
    )
    mult = HA.computation_multipliers(HA.parse_module(text))
    assert mult["body"] == 11.0


def test_trip_count_unrecoverable_defaults_to_one():
    """Data-dependent loop (no constant in the condition): multiplier
    conservatively defaults to 1 — the analysis pass reports HLO001."""
    text = _single_while(
        "  %i = s32[] get-tuple-element(%pc), index=0\n"
        "  %j = s32[] get-tuple-element(%pc), index=0\n"
        "  ROOT %lt = pred[] compare(%i, %j), direction=LT"
    )
    mult = HA.computation_multipliers(HA.parse_module(text))
    assert mult["body"] == 1.0


def test_dot_flops_from_named_operands():
    comps = HA.parse_module(
        """HloModule m

ENTRY %main (a: f32[8,32], b: f32[32,16]) -> f32[8,16] {
  %a = f32[8,32]{1,0} parameter(0)
  %b = f32[32,16]{1,0} parameter(1)
  ROOT %d = f32[8,16]{1,0} dot(%a, %b), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}
"""
    )
    main = comps["main"]
    dot = [i for i in main.instructions if i.op == "dot"][0]
    assert HA.dot_flops(dot, main.shapes) == 2 * 8 * 16 * 32

"""Checkpoint layer: atomicity, roundtrip, resume semantics, drift guard."""
from __future__ import annotations

import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import ckpt as CK


@pytest.fixture()
def tree():
    rng = np.random.default_rng(0)
    return {
        "params": {"w": jnp.asarray(rng.normal(size=(8, 4)).astype(np.float32)),
                   "b": jnp.asarray(rng.normal(size=(4,)).astype(np.float32))},
        "opt": {"step": jnp.asarray(7, jnp.int32)},
    }


def test_roundtrip(tmp_path, tree):
    CK.save(str(tmp_path), tree, step=3, async_write=False)
    out = CK.restore(str(tmp_path), tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_latest_step_and_multiple(tmp_path, tree):
    for s in (1, 5, 3):
        CK.save(str(tmp_path), tree, step=s, async_write=False)
    assert CK.latest_step(str(tmp_path)) == 5
    out = CK.restore(str(tmp_path), tree, step=3)
    assert out is not None


def test_async_write_visible_after_wait(tmp_path, tree):
    CK.save(str(tmp_path), tree, step=9, async_write=True)
    CK.wait_all()
    assert CK.latest_step(str(tmp_path)) == 9


def test_crashed_tmp_dir_is_ignored_and_cleaned(tmp_path, tree):
    """A stale .tmp (crash mid-write) must not count as a checkpoint and
    must be garbage-collected by the next save."""
    stale = os.path.join(str(tmp_path), "step_00000002.tmp")
    os.makedirs(stale)
    assert CK.latest_step(str(tmp_path)) is None
    CK.save(str(tmp_path), tree, step=2, async_write=False)
    assert not os.path.exists(stale)
    assert CK.latest_step(str(tmp_path)) == 2


def test_template_drift_is_caught(tmp_path, tree):
    CK.save(str(tmp_path), tree, step=1, async_write=False)
    bad = {"params": {"w": tree["params"]["w"]}}  # fewer leaves
    with pytest.raises(AssertionError, match="config drift"):
        CK.restore(str(tmp_path), bad)


def test_restore_casts_to_template_dtype(tmp_path, tree):
    CK.save(str(tmp_path), tree, step=1, async_write=False)
    cast = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, jnp.bfloat16)
        if x.dtype == jnp.float32 else x,
        tree,
    )
    out = CK.restore(str(tmp_path), cast)
    assert out["params"]["w"].dtype == jnp.bfloat16


def test_bf16_roundtrip(tmp_path):
    """npz cannot store ml_dtypes natively; the uint16-view path must
    round-trip bf16 bit-exactly (production params are bf16)."""
    rng = np.random.default_rng(1)
    t = {"w": jnp.asarray(rng.normal(size=(16, 8)).astype(np.float32)).astype(jnp.bfloat16)}
    CK.save(str(tmp_path), t, step=1, async_write=False)
    out = CK.restore(str(tmp_path), t)
    assert out["w"].dtype == jnp.bfloat16
    np.testing.assert_array_equal(
        np.asarray(out["w"]).view(np.uint16), np.asarray(t["w"]).view(np.uint16)
    )


def test_restore_with_shardings_places(tmp_path, tree):
    """Elastic-restore path: shardings tree is honoured (trivially on the
    single CPU device, but the code path is exercised)."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    mesh = jax.make_mesh((1,), ("data",))
    CK.save(str(tmp_path), tree, step=1, mesh_shape=(1,), async_write=False)
    shardings = jax.tree.map(lambda x: NamedSharding(mesh, P()), tree)
    out = CK.restore(str(tmp_path), tree, shardings=shardings)
    assert out["params"]["w"].sharding == NamedSharding(mesh, P())

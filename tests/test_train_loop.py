"""Training loop: loss goes down, microbatch-accumulation equivalence,
compression path, trainer checkpoint/resume determinism."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import ckpt as CK
from repro.configs import get_config
from repro.models.model import build
from repro.optim.grad_compress import init_error_state
from repro.optim.optimizers import adamw, sgd
from repro.training.train_loop import Trainer, make_train_step
from repro.data.tokens import CorpusConfig, SyntheticCorpus


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("tiny_dense")
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    corpus = SyntheticCorpus(CorpusConfig(vocab_size=cfg.vocab_size))
    return model, params, corpus


def _data_fn(corpus, batch, seq):
    def f(step: int):
        r = np.random.default_rng(1000 + step)
        return {"tokens": jnp.asarray(
            np.stack([corpus.sample(r, seq) for _ in range(batch)])
        )}
    return f


def test_loss_decreases(setup):
    model, params, corpus = setup
    opt = adamw(3e-3)
    step = jax.jit(make_train_step(model.loss, opt))
    data = _data_fn(corpus, 16, 64)
    opt_state = opt.init(params)
    losses = []
    for i in range(30):
        params, opt_state, m, _ = step(params, opt_state, data(i), None)
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) * 0.9


def test_microbatch_grad_equivalence(setup):
    """grads(microbatches=4) must equal grads(microbatches=1) — SGD single
    step comparison (Adam would amplify tiny numeric diffs)."""
    model, params, corpus = setup
    batch = _data_fn(corpus, 8, 64)(0)
    opt = sgd(1e-2)
    s1 = make_train_step(model.loss, opt, microbatches=1)
    s4 = make_train_step(model.loss, opt, microbatches=4)
    p1, *_ = s1(params, opt.init(params), batch, None)
    p4, *_ = s4(params, opt.init(params), batch, None)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p4)):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32),
            rtol=2e-4, atol=2e-5,
        )


def test_grad_clipping_applied(setup):
    model, params, corpus = setup
    batch = _data_fn(corpus, 8, 64)(0)
    opt = sgd(1.0)
    step = make_train_step(model.loss, opt, grad_clip=1e-9)
    p2, _, m, _ = step(params, opt.init(params), batch, None)
    # with a near-zero clip the params barely move
    delta = max(
        float(jnp.abs(a - b).max())
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2))
    )
    assert delta < 1e-6


def test_compression_path_trains(setup):
    model, params, corpus = setup
    opt = adamw(3e-3)
    step = jax.jit(make_train_step(model.loss, opt, compress_ratio=0.1))
    err = init_error_state(params)
    data = _data_fn(corpus, 16, 64)
    opt_state = opt.init(params)
    losses = []
    for i in range(20):
        params, opt_state, m, err = step(params, opt_state, data(i), err)
        losses.append(float(m["loss"]))
    assert np.isfinite(losses).all()
    assert np.mean(losses[-5:]) < np.mean(losses[:5])


def test_trainer_checkpoint_resume_bitexact(setup, tmp_path):
    """Fault tolerance: run 6 steps straight vs 3 + crash + resume 3 —
    identical final params (deterministic data order by step)."""
    model, params0, corpus = setup
    opt = adamw(1e-3)
    data = _data_fn(corpus, 8, 64)
    step = jax.jit(make_train_step(model.loss, opt))

    # straight run
    p, s = params0, opt.init(params0)
    for i in range(6):
        p, s, _, _ = step(p, s, data(i), None)
    straight = p

    # checkpointed run
    ck = str(tmp_path / "ck")
    tr = Trainer(step_fn=step, data_fn=data, ckpt_dir=ck, ckpt_every=3, log_every=100)
    p, s = params0, opt.init(params0)
    p, s, _ = tr.run(p, s, 0, 3)
    CK.wait_all()
    # “crash”: reload from disk
    restored = CK.restore(ck, {"params": p, "opt_state": s})
    p2, s2 = restored["params"], restored["opt_state"]
    start = CK.latest_step(ck)
    assert start == 3
    p2, s2, _ = tr.run(p2, s2, start, 3)

    for a, b in zip(jax.tree.leaves(straight), jax.tree.leaves(p2)):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32),
            rtol=1e-5, atol=1e-6,
        )

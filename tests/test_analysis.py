"""repro.analysis: one positive (seeded violation caught) and one negative
(clean input stays clean) test per pass, plus CLI exit-code behaviour."""
from __future__ import annotations

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from analysis_fixtures import BAD_HEADS, BAD_TILES
from repro.analysis import Finding, Report, run
from repro.analysis.config_check import (
    check_ebft_mesh_plan,
    check_hlo_text,
    check_model_config,
    check_sharding,
)
from repro.analysis.jaxpr_lint import lint_jaxpr
from repro.analysis.kernel_check import check_config_kernels, matmul_workloads
from repro.analysis.mask_check import check_mask_tree, check_masked_fn
from repro.analysis.source_lint import check_sources
from repro.configs import get_config
from repro.kernels.validation import (
    BlockUse,
    KernelPlan,
    pick_tile,
    plan_masked_matmul,
)
from repro.sparsity import sparse_params as SP


def codes(findings):
    return {f.code for f in findings}


def errors(findings):
    return [f for f in findings if f.severity == "error"]


# ---------------------------------------------------------------------------
# findings / report plumbing
# ---------------------------------------------------------------------------
def test_report_exit_code_thresholds():
    r = Report(findings=[
        Finding("X001", "warn", "kernels", "w"),
        Finding("X002", "info", "kernels", "i"),
    ])
    assert r.exit_code("error") == 0
    assert r.exit_code("warn") == 1
    assert r.exit_code("info") == 1
    assert r.exit_code("never") == 0
    assert r.max_severity() == "warn"


def test_report_ignore_filters_codes():
    r = Report(findings=[
        Finding("X001", "error", "kernels", "e"),
        Finding("X002", "warn", "kernels", "w"),
    ])
    assert r.without(["X001"]).exit_code("error") == 0
    assert r.without([]).exit_code("error") == 1


def test_finding_rejects_bad_severity():
    with pytest.raises(ValueError):
        Finding("X", "fatal", "kernels", "msg")


# ---------------------------------------------------------------------------
# pass 1: kernels
# ---------------------------------------------------------------------------
def test_pick_tile_selection():
    assert pick_tile(8192, 128) == 128
    assert pick_tile(10944, 128) == 64      # deepseek_moe_16b's d_ff
    assert pick_tile(64, 128) == 64         # clamp: whole dim in one tile
    assert pick_tile(999, 128) is None      # odd, >128: no viable tile
    assert pick_tile(10944, 128, multiple_of=4) == 64
    assert pick_tile(96, 128, multiple_of=64) is None


def test_kernel_pass_flags_untileable_config():
    findings = check_config_kernels("bad_tiles", BAD_TILES)
    ker = [f for f in errors(findings) if f.code == "KER001"]
    assert ker, findings
    assert any("w_up" in f.location or "w_down" in f.location for f in ker)


def test_kernel_pass_clean_on_shipped_config():
    for name in ("tiny_dense", "llama_7b", "deepseek_moe_16b"):
        findings = check_config_kernels(name, get_config(name))
        assert not errors(findings), (name, findings)


def test_kernel_vmem_budget_flagged():
    # 1024x1024 f32 tiles: 2x(4+4+1+4 MiB streamed) + 4 MiB scratch = 30 MiB
    plan = plan_masked_matmul(4096, 4096, 4096, bm=1024, bk=1024, bn=1024)
    from repro.analysis.kernel_check import _vmem_findings

    found = _vmem_findings(plan, "cfg", "loc")
    assert "KER002" in codes(found)


def test_kernel_index_map_arity_checked():
    plan = KernelPlan(
        kernel="k", grid=(4, 4),
        inputs=(BlockUse("x", (8, 8), jnp.float32, lambda i: (i, 0)),),
        outputs=(), scratch=(),
    )
    errs = plan.index_map_arity_errors()
    assert errs and "takes 1 args" in errs[0] and "rank 2" in errs[0]


def test_matmul_workloads_cover_families():
    labels = {l for l, *_ in matmul_workloads(get_config("tiny_moe"))}
    assert {"wq", "wo", "expert_up", "expert_down"} <= labels
    labels = {l for l, *_ in matmul_workloads(get_config("tiny_ssm"))}
    assert {"in_z", "ssm_out"} <= labels and "wq" not in labels


# ---------------------------------------------------------------------------
# pass 2: masks
# ---------------------------------------------------------------------------
def _weights_and_masks(key=0):
    w = {"w_up": jax.random.normal(jax.random.PRNGKey(key), (16, 8))}
    masks = SP.ones_masks(w)
    return w, masks


def test_mask_check_flags_unmasked_dot():
    w, masks = _weights_and_masks()
    x = jnp.ones((4, 16))

    def bad_loss(weights, masks, x):
        return jnp.sum(x @ weights["w_up"])  # mask never applied

    findings = check_masked_fn(bad_loss, w, masks, x)
    assert "MSK001" in codes(findings)
    assert errors(findings)


def test_mask_check_accepts_masked_dot():
    w, masks = _weights_and_masks()
    x = jnp.ones((4, 16))

    def good_loss(weights, masks, x):
        return jnp.sum(x @ (weights["w_up"] * masks["w_up"]))

    assert check_masked_fn(good_loss, w, masks, x) == []


def test_mask_check_sees_through_scan():
    """The taint must follow a weight carried into lax.scan."""
    w, masks = _weights_and_masks()
    x = jnp.ones((4, 16))

    def scan_loss(weights, masks, x):
        def body(h, _):
            return h @ weights["w_up"] @ weights["w_up"].T, None

        h, _ = jax.lax.scan(body, x, None, length=3)
        return jnp.sum(h)

    assert "MSK001" in codes(check_masked_fn(scan_loss, w, masks, x))


def test_mask_check_real_block_loss_is_masked():
    """The shipped reconstruction.block_loss masks before contracting."""
    from repro.core import reconstruction as R
    from repro.models.model import build

    cfg = get_config("tiny_dense", smoke=True)
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    bw = model.get_block(params, 0)
    masks_b = SP.ones_masks(bw)
    h = jnp.zeros((2, 8, cfg.d_model), jnp.dtype(cfg.dtype))
    pos = jnp.arange(8)[None, :]

    def loss(bw_, masks_, h_, pos_):
        return R.block_loss(model, 0, bw_, masks_, h_, h_, pos_, {})

    assert check_masked_fn(loss, bw, masks_b, h, pos) == []


def test_mask_tree_nm_pattern_validation():
    w = {"w_up": jax.random.normal(jax.random.PRNGKey(1), (16, 8))}
    mat, tag = SP.to_matrix("w_up", jnp.abs(w["w_up"]))
    good = {"w_up": SP.from_matrix(SP.nm_mask(mat, 2, 4), tag)}
    assert check_mask_tree(good, w, nm=(2, 4)) == []

    # tamper one element: a 2:4 group now keeps 3 (or 1) -> MSK003
    bad_arr = np.asarray(good["w_up"]).copy()
    bad_arr[0, 0] = 1.0 - bad_arr[0, 0]
    bad = {"w_up": jnp.asarray(bad_arr)}
    assert "MSK003" in codes(check_mask_tree(bad, w, nm=(2, 4)))


def test_mask_tree_rejects_nonbinary():
    w = {"w_up": jnp.ones((8, 4))}
    soft = {"w_up": jnp.full((8, 4), 0.5)}
    assert "MSK002" in codes(check_mask_tree(soft, w))


# ---------------------------------------------------------------------------
# pass 3: jaxpr lint
# ---------------------------------------------------------------------------
def test_lint_flags_host_callback():
    def f(x):
        jax.debug.print("x={x}", x=x)
        return x * 2

    closed = jax.make_jaxpr(f)(jnp.ones((4,)))
    findings = lint_jaxpr(closed, where="t")
    assert "LNT002" in codes(findings) and errors(findings)


def test_lint_flags_silent_widening():
    def f(x):
        return x.astype(jnp.float32) + 1.0  # widen bf16 -> f32 for an add

    closed = jax.make_jaxpr(f)(jnp.ones((4,), jnp.bfloat16))
    assert "LNT001" in codes(lint_jaxpr(closed, where="t"))


def test_lint_allows_accumulator_widening():
    def f(x, w):
        # widening straight into a contraction is the accumulator idiom
        return jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32))

    closed = jax.make_jaxpr(f)(
        jnp.ones((4, 4), jnp.bfloat16), jnp.ones((4, 4), jnp.bfloat16)
    )
    findings = lint_jaxpr(closed, where="t")
    assert "LNT001" not in codes(findings)
    assert "LNT002" not in codes(findings)


def test_lint_flags_convert_round_trip():
    def f(x):
        return x.astype(jnp.bfloat16).astype(jnp.float32) * 2.0

    closed = jax.make_jaxpr(f)(jnp.ones((4,), jnp.float32))
    assert "LNT003" in codes(lint_jaxpr(closed, where="t"))


def test_lint_clean_function_is_clean():
    def f(x, w):
        return jnp.tanh(x @ w)

    closed = jax.make_jaxpr(f)(jnp.ones((4, 4)), jnp.ones((4, 4)))
    assert lint_jaxpr(closed, where="t") == []


# ---------------------------------------------------------------------------
# pass 4: config / sharding / HLO
# ---------------------------------------------------------------------------
def test_config_check_flags_head_mismatch():
    findings = check_model_config("bad_heads", BAD_HEADS)
    assert "CFG002" in codes(findings) and errors(findings)


def test_config_check_flags_indivisible_d_model():
    cfg = BAD_HEADS.replace(name="bad_dm", num_heads=3, num_kv_heads=3,
                            head_dim=0, d_model=64)
    assert "CFG001" in codes(check_model_config("bad_dm", cfg))


def test_config_and_sharding_clean_on_shipped():
    for name in ("tiny_dense", "llama_7b"):
        cfg = get_config(name)
        assert not errors(check_model_config(name, cfg)), name
        assert not errors(check_sharding(name, cfg)), name


def test_sharding_warns_on_nondivisible_heads():
    # llama_7b: 32 heads / model axis 16 divides -> no SHD003
    assert "SHD003" not in codes(check_sharding("llama_7b", get_config("llama_7b")))
    # qwen1_5_4b: 20 heads -> pad fallback warn
    f = check_sharding("qwen1_5_4b", get_config("qwen1_5_4b"))
    shd = [x for x in f if x.code == "SHD003"]
    assert shd and shd[0].severity == "warn"


_HLO_BAD_GROUPS = """HloModule m

ENTRY %main (p0: f32[16]) -> f32[16] {
  %p0 = f32[16]{0} parameter(0)
  ROOT %ar = f32[16]{0} all-reduce(%p0), replica_groups={{0,1,2}}, to_apply=%add
}
"""

_HLO_OPAQUE_WHILE = """HloModule m

%cond (pc: (s32[], f32[4])) -> pred[] {
  %pc = (s32[], f32[4]) parameter(0)
  %i = s32[] get-tuple-element(%pc), index=0
  %j = s32[] get-tuple-element(%pc), index=0
  ROOT %lt = pred[] compare(%i, %j), direction=LT
}

%body (pb: (s32[], f32[4])) -> (s32[], f32[4]) {
  %pb = (s32[], f32[4]) parameter(0)
  ROOT %same = (s32[], f32[4]) copy(%pb)
}

ENTRY %main (a: (s32[], f32[4])) -> (s32[], f32[4]) {
  %a = (s32[], f32[4]) parameter(0)
  ROOT %w = (s32[], f32[4]) while(%a), condition=%cond, body=%body
}
"""


def test_hlo_check_flags_bad_replica_groups():
    findings = check_hlo_text(_HLO_BAD_GROUPS, total_devices=256)
    assert "HLO002" in codes(findings) and errors(findings)


def test_hlo_check_flags_opaque_trip_count():
    findings = check_hlo_text(_HLO_OPAQUE_WHILE, total_devices=8)
    assert "HLO001" in codes(findings)
    assert not errors(findings)  # warn, not error


def test_hlo_check_clean_on_tiled_groups():
    text = _HLO_BAD_GROUPS.replace("{{0,1,2}}", "[16,16]<=[256]")
    assert check_hlo_text(text, total_devices=256) == []


# ---------------------------------------------------------------------------
# source_lint
# ---------------------------------------------------------------------------
def test_source_lint_flags_seeded_violations(tmp_path):
    core = tmp_path / "core"
    core.mkdir()
    (core / "hot.py").write_text(
        "import time\n"
        "t0 = time.time()\n"              # OBS002
        "print('debug')\n"                # OBS001 (hot path)
        "# print('in a comment is fine')\n"
        "pprint(x)\n"                     # not print()
        "obj.print()\n"                   # method call, not builtin
    )
    launch = tmp_path / "launch"
    launch.mkdir()
    (launch / "cli.py").write_text(
        "print('drivers may print')\n"
        "import time; t = time.time()\n"  # OBS002 applies everywhere
    )
    findings = check_sources(src_root=str(tmp_path))
    got = codes(findings)
    assert got == {"OBS001", "OBS002"}
    obs1 = [f for f in findings if f.code == "OBS001"]
    assert len(obs1) == 1 and "core/hot.py:3" in obs1[0].location
    obs2 = [f for f in findings if f.code == "OBS002"]
    assert len(obs2) == 2
    assert not errors(findings)  # hygiene findings are warn-severity


def test_source_lint_obs003_in_loop_host_syncs(tmp_path):
    core = tmp_path / "core"
    core.mkdir()
    (core / "hot.py").write_text(
        "import numpy as np\n"
        "x = float(y)\n"                          # out of loop: fine
        "for i in range(3):\n"
        "    a = float(z[i])\n"                   # OBS003 (line 4)
        "    b = np.asarray(z[i])\n"              # OBS003 (line 5)
        "    c = jnp.asarray(z[i])\n"             # jnp staging: fine
        "    d = float(w)  # obs: sync-ok why\n"  # suppressed inline
        "    # obs: sync-ok (epoch mean)\n"
        "    e = float(v)\n"                      # suppressed by prev line
        "while cond:\n"
        "    f = float(q)\n"                      # OBS003 (line 11)
        "g = float(done)\n"                       # loop exited: fine
    )
    launch = tmp_path / "launch"
    launch.mkdir()
    (launch / "cli.py").write_text(
        "for i in range(3):\n"
        "    x = float(y[i])\n"  # not a hot-path package
    )
    findings = check_sources(src_root=str(tmp_path))
    obs3 = [f for f in findings if f.code == "OBS003"]
    assert sorted(f.location for f in obs3) == [
        "repro/core/hot.py:11", "repro/core/hot.py:4", "repro/core/hot.py:5",
    ]
    assert not errors(findings)


def test_source_lint_obs003_nested_loop_scope(tmp_path):
    core = tmp_path / "core"
    core.mkdir()
    (core / "nest.py").write_text(
        "for i in range(3):\n"
        "    for j in range(3):\n"
        "        a = float(x[i][j])\n"  # OBS003 (inner)
        "    b = float(y[i])\n"  # OBS003 (outer loop still open)
        "c = float(z)\n"         # all loops closed: fine
    )
    findings = check_sources(src_root=str(tmp_path))
    assert sorted(f.location for f in findings if f.code == "OBS003") == [
        "repro/core/nest.py:3", "repro/core/nest.py:4",
    ]


def test_source_lint_clean_tree_and_real_repo(tmp_path):
    obs = tmp_path / "obs"
    obs.mkdir()
    (obs / "impl.py").write_text("import time\nnow = time.time()\n")
    clean = tmp_path / "core"
    clean.mkdir()
    (clean / "ok.py").write_text("import time\nt = time.perf_counter()\n")
    assert check_sources(src_root=str(tmp_path)) == []
    # the shipped tree itself must stay clean (this is the CI invariant)
    assert check_sources() == []


# ---------------------------------------------------------------------------
# orchestrator + CLI
# ---------------------------------------------------------------------------
def test_run_clean_on_tiny_config():
    report = run(config_names=["tiny_dense"])
    assert report.exit_code("error") == 0
    assert report.passes_run == [
        "kernels", "masks", "jaxpr", "sharding", "source_lint",
        "tuning_cache",
    ]
    assert report.configs_checked == ["tiny_dense"]


def test_run_seeded_violations_fail(capsys):
    report = run(
        config_names=["tiny_dense"],
        passes=["kernels", "sharding"],
        extra_configs=[("bad_tiles", BAD_TILES), ("bad_heads", BAD_HEADS)],
    )
    assert report.exit_code("error") == 1
    assert {"KER001", "CFG002"} <= codes(report.findings)
    # and --ignore-style filtering rescues it
    clean = report.without(["KER001", "CFG002", "ANA000"])
    assert clean.exit_code("error") == 0


def test_run_rejects_unknown_pass():
    with pytest.raises(ValueError):
        run(config_names=["tiny_dense"], passes=["typo"])


def test_cli_exit_codes_and_json(capsys):
    from repro.analysis.__main__ import main

    rc = main(["--configs", "tiny_dense", "--passes", "kernels", "sharding",
               "--json"])
    payload = json.loads(capsys.readouterr().out)
    assert rc == 0
    assert payload["counts"]["error"] == 0
    assert payload["configs"] == ["tiny_dense"]

    rc = main(["--configs", "tiny_dense", "--passes", "kernels", "sharding",
               "--extra-config-module", "analysis_fixtures", "--json"])
    payload = json.loads(capsys.readouterr().out)
    assert rc == 1
    got = {f["code"] for f in payload["findings"]}
    assert {"KER001", "CFG002"} <= got

    rc = main(["--configs", "tiny_dense", "--passes", "kernels", "sharding",
               "--extra-config-module", "analysis_fixtures",
               "--fail-on", "never", "-q"])
    capsys.readouterr()
    assert rc == 0


# ---------------------------------------------------------------------------
# SHD005: EBFT mesh-plan divisibility fallbacks
# ---------------------------------------------------------------------------
def test_mesh_plan_clean_layout_has_no_findings():
    # tiny_dense on a (4, 2) mesh with microbatch 8: batch and every
    # ruled block leaf divide, so the walk runs fully sharded
    fs = check_ebft_mesh_plan("tiny_dense", get_config("tiny_dense"),
                              data=4, model_axis=2, microbatch=8)
    assert fs == []


def test_mesh_plan_flags_indivisible_microbatch():
    fs = check_ebft_mesh_plan("tiny_dense", get_config("tiny_dense"),
                              data=4, model_axis=2, microbatch=7)
    assert any(f.code == "SHD005" and "microbatch=7" in f.message
               for f in fs)
    assert all(f.severity == "warn" for f in fs)


def test_mesh_plan_flags_block_replication_fallback():
    # 4 heads on a model axis of 16: the attention leaves have a sharding
    # rule but fail divisibility, so they replicate — one aggregated warn
    fs = check_ebft_mesh_plan("tiny_dense", get_config("tiny_dense"),
                              data=4, model_axis=16, microbatch=8)
    hits = [f for f in fs if f.code == "SHD005"
            and f.location == "ebft.block0"]
    assert len(hits) == 1
    assert "attn/wq" in hits[0].message


# ---------------------------------------------------------------------------
# API001: deprecated launcher flags in in-repo callers
# ---------------------------------------------------------------------------
def test_deprecated_flag_lint(tmp_path):
    from repro.analysis.source_lint import check_deprecated_flags

    (tmp_path / "tests").mkdir()
    (tmp_path / "tests" / "t.py").write_text(
        'run(["--ebft-epochs", "4"])\n'  # api: deprecated-ok
        'run(["--ebft-lr", "0.1"])  # api: deprecated-ok\n'
        'run(["--epochs", "4"])\n'
    )
    fs = check_deprecated_flags(repo_root=str(tmp_path))
    assert len(fs) == 1
    f = fs[0]
    assert f.code == "API001" and f.severity == "error"
    assert "--ebft-epochs" in f.message  # api: deprecated-ok
    assert "--epochs" in f.message
    assert f.location.endswith("t.py:1")


def test_deprecated_flag_lint_repo_is_clean():
    from repro.analysis.source_lint import check_deprecated_flags

    assert check_deprecated_flags() == []

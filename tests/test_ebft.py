"""EBFT integration: the paper's core claims at miniature scale.

1. Block-wise reconstruction error decreases monotonically-ish per block.
2. Masks are frozen: pruned slots stay exactly zero after fine-tuning.
3. Held-out perplexity improves over the un-fine-tuned sparse model at
   high sparsity (Tab. 1 ordering: EBFT < no-FT).
4. The mask-tuning ablation (Tab. 6) runs and keeps the target sparsity.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ebft, mask_tuning
from repro.core.evaluate import cloze_accuracy, perplexity
from repro.core.masks import prune
from repro.data.tokens import cloze_task
from repro.sparsity import sparse_params as SP

ECFG = ebft.EBFTConfig(lr=1e-2, epochs=8, microbatch=8, patience=3)


@pytest.fixture(scope="module")
def pruned_setup(trained_tiny_dense, tiny_calib):
    model, params = trained_tiny_dense
    masks, pruned = prune(model, params, tiny_calib, method="wanda", sparsity=0.7)
    return model, params, masks, pruned


@pytest.fixture(scope="module")
def tuned_setup(pruned_setup, tiny_calib):
    model, params, masks, pruned = pruned_setup
    tuned, reports = ebft.finetune(model, params, pruned, masks, tiny_calib, ECFG)
    return model, params, masks, pruned, tuned, reports


def test_reconstruction_error_decreases(tuned_setup):
    *_, reports = tuned_setup
    assert len(reports) > 0
    for r in reports:
        assert r.loss_after <= r.loss_before * 1.001, (
            f"block {r.index}: E {r.loss_before} -> {r.loss_after}"
        )
    # aggregate drop must be substantial
    drop = sum(r.loss_before - r.loss_after for r in reports)
    assert drop > 0


def test_masks_frozen_pruned_slots_zero(tuned_setup):
    model, params, masks, pruned, tuned, _ = tuned_setup

    def check(path, w, m):
        if SP.is_prunable(path, w):
            dead = np.asarray(m) == 0
            assert np.all(np.asarray(w, np.float32)[dead] == 0.0)
        return w

    jax.tree_util.tree_map_with_path(check, tuned, masks)


def test_surviving_weights_moved(tuned_setup):
    model, params, masks, pruned, tuned, _ = tuned_setup
    moved = any(
        float(jnp.abs(a - b).max()) > 1e-8
        for a, b in zip(jax.tree.leaves(pruned), jax.tree.leaves(tuned))
    )
    assert moved


def test_perplexity_improves_over_pruned(tuned_setup, tiny_eval):
    model, params, masks, pruned, tuned, _ = tuned_setup
    ppl_pruned = perplexity(model, pruned, tiny_eval)
    ppl_tuned = perplexity(model, tuned, tiny_eval)
    assert ppl_tuned < ppl_pruned, (
        f"EBFT must improve held-out ppl: {ppl_pruned:.2f} -> {ppl_tuned:.2f}"
    )


def test_cloze_not_degraded(tuned_setup, tiny_corpus):
    """Zero-shot-suite stand-in: EBFT should not hurt the ranking task."""
    model, params, masks, pruned, tuned, _ = tuned_setup
    ctx, true_next, distract = cloze_task(tiny_corpus, 64, 64)
    acc_pruned = cloze_accuracy(model, pruned, ctx, true_next, distract)
    acc_tuned = cloze_accuracy(model, tuned, ctx, true_next, distract)
    assert acc_tuned >= acc_pruned - 0.05


def test_mask_tuning_preserves_sparsity_and_weights(pruned_setup, tiny_calib):
    model, params, masks, pruned = pruned_setup
    mt_params, mt_masks = mask_tuning.finetune_masks(
        model, params, masks, 0.7, tiny_calib,
        ebft.EBFTConfig(lr=2e-2, epochs=2, microbatch=8),
    )
    s = SP.sparsity_of(mt_masks, params)
    assert abs(s - 0.7) < 0.03
    # weights under the mask must be the DENSE weights (mask tuning never
    # updates values)
    def check(path, w_dense, w_mt, m):
        if SP.is_prunable(path, w_dense):
            live = np.asarray(m) > 0
            np.testing.assert_allclose(
                np.asarray(w_dense, np.float32)[live],
                np.asarray(w_mt, np.float32)[live], rtol=1e-6,
            )
        return w_dense

    jax.tree_util.tree_map_with_path(check, params, mt_params, mt_masks)


def test_ebft_on_nm_pattern(trained_tiny_dense, tiny_calib, tiny_eval):
    """Tab. 2: EBFT under 2:4 sparsity improves over the pruned model."""
    model, params = trained_tiny_dense
    masks, pruned = prune(model, params, tiny_calib, method="wanda",
                          sparsity=0.5, pattern=(2, 4))
    tuned, _ = ebft.finetune(model, params, pruned, masks, tiny_calib,
                             ebft.EBFTConfig(lr=1e-2, epochs=4, microbatch=8))
    ppl_pruned = perplexity(model, pruned, tiny_eval)
    ppl_tuned = perplexity(model, tuned, tiny_eval)
    assert ppl_tuned < ppl_pruned * 1.02


@pytest.mark.parametrize("arch", ["tiny_moe", "tiny_ssm"])
def test_ebft_runs_on_other_families(arch, tiny_calib):
    """EBFT applies to every assigned family (DESIGN.md §5): the walk,
    per-block tuning, and the frozen-mask invariant hold beyond dense."""
    from repro.configs import get_config
    from repro.models.model import build

    cfg = get_config(arch)
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    calib = tiny_calib[:8]
    masks, pruned = prune(model, params, calib, method="magnitude", sparsity=0.5)
    tuned, reports = ebft.finetune(
        model, params, pruned, masks, calib,
        ebft.EBFTConfig(lr=1e-3, epochs=2, microbatch=4),
    )
    assert len(reports) == model.num_blocks or len(reports) > 0
    for r in reports:
        assert np.isfinite(r.loss_after)

    def check(path, w, m):
        if SP.is_prunable(path, w):
            dead = np.asarray(m) == 0
            assert np.all(np.asarray(w, np.float32)[dead] == 0.0)
        return w

    jax.tree_util.tree_map_with_path(check, tuned, masks)

"""Assigned-architecture smoke tests (assignment requirement): for each of
the 10 archs, instantiate a REDUCED config of the same family and run one
forward + one train step on CPU, asserting output shapes and no NaNs.

The reduction shrinks depth/width/experts/vocab but preserves every
family-defining feature of the full config (GQA ratio, QKV bias,
activation, MoE top-k + shared experts, SSD state, shared-attn cadence,
enc-dec split, modality frontends)."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_configs
from repro.configs.base import ShapeConfig
from repro.models.model import build
from repro.optim.optimizers import adamw
from repro.training.train_loop import make_train_step
from tests.conftest import make_batch

SHAPE = ShapeConfig("smoke", 64, 2, "train")


def reduce_config(cfg):
    """Shrink a full config to test scale, preserving family features."""
    kw = dict(
        num_layers=2,
        d_model=64,
        vocab_size=512,
        d_ff=128 if cfg.d_ff else 0,
        max_position=1024,
    )
    # heads: keep the GQA ratio
    if cfg.num_heads:
        ratio = max(1, cfg.num_heads // max(cfg.num_kv_heads, 1))
        kw["num_kv_heads"] = max(1, 4 // ratio) if ratio <= 4 else 1
        kw["num_heads"] = kw["num_kv_heads"] * ratio
        kw["head_dim"] = 64 // max(kw["num_heads"], 1) or 16
    if cfg.moe_num_experts:
        kw.update(moe_num_experts=8, moe_top_k=min(cfg.moe_top_k, 2),
                  moe_d_ff=32, moe_first_dense=min(cfg.moe_first_dense, 1))
    if cfg.ssm_state:
        kw.update(ssm_state=16, ssm_head_dim=16, ssm_chunk=16)
    if cfg.hybrid_attn_every:
        kw.update(hybrid_attn_every=min(cfg.hybrid_attn_every, 2))
    if cfg.enc_layers:
        kw.update(enc_layers=2)
    if cfg.frontend_len:
        kw.update(frontend_len=8)
    return cfg.replace(**kw)


@pytest.mark.parametrize("arch", list_configs())
def test_reduced_arch_forward_and_train_step(arch):
    full = get_config(arch)
    cfg = reduce_config(full)
    assert cfg.family == full.family
    assert cfg.qkv_bias == full.qkv_bias
    assert cfg.mlp_act == full.mlp_act
    m = build(cfg)
    params = m.init(jax.random.PRNGKey(0))
    batch = make_batch(m, SHAPE, np.random.default_rng(0))

    logits = m.forward(params, batch)
    assert logits.shape[-1] == cfg.padded_vocab
    assert bool(jnp.all(jnp.isfinite(logits))), f"{arch}: NaN in logits"

    opt = adamw(1e-3)
    step = jax.jit(make_train_step(m.loss, opt))
    opt_state = opt.init(params)
    params2, _, metrics, _ = step(params, opt_state, batch, None)
    assert bool(jnp.isfinite(metrics["loss"])), f"{arch}: NaN loss"
    # params actually moved
    moved = any(
        float(jnp.abs(a - b).max()) > 0
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(params2))
    )
    assert moved, f"{arch}: train step did not update params"


@pytest.mark.parametrize("arch", list_configs())
def test_full_config_matches_assignment(arch):
    """The full configs must carry the exact assigned hyperparameters."""
    expected = {
        "qwen1_5_4b": dict(num_layers=40, d_model=2560, num_heads=20,
                           num_kv_heads=20, d_ff=6912, vocab_size=151936,
                           qkv_bias=True, family="dense"),
        "nemotron_4_15b": dict(num_layers=32, d_model=6144, num_heads=48,
                               num_kv_heads=8, d_ff=24576, vocab_size=256000,
                               mlp_act="sq_relu", family="dense"),
        "qwen2_5_32b": dict(num_layers=64, d_model=5120, num_heads=40,
                            num_kv_heads=8, d_ff=27648, vocab_size=152064,
                            qkv_bias=True, family="dense"),
        "qwen1_5_110b": dict(num_layers=80, d_model=8192, num_heads=64,
                             num_kv_heads=8, d_ff=49152, vocab_size=152064,
                             qkv_bias=True, family="dense"),
        "zamba2_1_2b": dict(num_layers=38, d_model=2048, num_heads=32,
                            num_kv_heads=32, d_ff=8192, vocab_size=32000,
                            ssm_state=64, family="hybrid"),
        "kimi_k2_1t_a32b": dict(num_layers=61, d_model=7168, num_heads=64,
                                num_kv_heads=8, moe_d_ff=2048, vocab_size=163840,
                                moe_num_experts=384, moe_top_k=8, family="moe"),
        "deepseek_moe_16b": dict(num_layers=28, d_model=2048, num_heads=16,
                                 num_kv_heads=16, moe_d_ff=1408, vocab_size=102400,
                                 moe_num_experts=64, moe_top_k=6,
                                 moe_num_shared=2, family="moe"),
        "seamless_m4t_medium": dict(num_layers=12, d_model=1024, num_heads=16,
                                    num_kv_heads=16, d_ff=4096, vocab_size=256206,
                                    family="encdec", enc_layers=12),
        "mamba2_130m": dict(num_layers=24, d_model=768, vocab_size=50280,
                            ssm_state=128, family="ssm"),
        "llava_next_mistral_7b": dict(num_layers=32, d_model=4096, num_heads=32,
                                      num_kv_heads=8, d_ff=14336, vocab_size=32000,
                                      family="vlm"),
    }[arch]
    cfg = get_config(arch)
    for k, v in expected.items():
        assert getattr(cfg, k) == v, f"{arch}.{k}: {getattr(cfg, k)} != {v}"


@pytest.mark.parametrize("arch", list_configs())
def test_shape_cells_follow_assignment_rules(arch):
    """long_500k only for sub-quadratic families; others get 4/3 shapes."""
    cfg = get_config(arch)
    names = [s.name for s in cfg.shapes()]
    assert "train_4k" in names and "prefill_32k" in names and "decode_32k" in names
    if cfg.family in ("ssm", "hybrid"):
        assert "long_500k" in names
    else:
        assert "long_500k" not in names

"""Distribution integration: lower+compile a sharded train/decode step on a
multi-device mesh. Needs >1 device, so it runs in a subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count=8 (the main test process
keeps the default single device, per the assignment)."""
from __future__ import annotations

import json
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp
from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.launch import steps as ST
from repro.launch import hlo_analysis as HA

cfg = get_config("tiny_dense").replace(num_layers=2)
mesh = jax.make_mesh((2, 4), ("data", "model"))
out = {}

# train cell
shape = ShapeConfig("t", 64, 8, "train")
cell = ST.build_train_cell(cfg, shape, mesh, microbatches=2, fsdp=False)
with mesh:
    compiled = ST.lower_cell(cell).compile()
ma = compiled.memory_analysis()
st = HA.analyze(compiled.as_text(), 8)
out["train"] = {
    "temp_bytes": ma.temp_size_in_bytes,
    "flops": st.flops,
    "collective_wire": st.collective_wire,
}

# run the compiled step with real (tiny) buffers to prove executability
params = jax.device_put(cell.model.init(jax.random.PRNGKey(0)), cell.in_shardings[0])
from repro.optim.optimizers import adamw
opt = adamw(1e-4)
opt_state = jax.device_put(opt.init(params), cell.in_shardings[1])
batch = jax.device_put(
    {"tokens": jnp.ones((8, 64), jnp.int32)}, cell.in_shardings[2]
)
jitted = jax.jit(cell.fn, in_shardings=cell.in_shardings,
                 out_shardings=cell.out_shardings,
                 donate_argnums=cell.donate_argnums)
with mesh:
    p2, o2, metrics = jitted(params, opt_state, batch)
out["train"]["loss_finite"] = bool(jnp.isfinite(metrics["loss"]))

# decode cell
shape_d = ShapeConfig("d", 256, 8, "decode")
cell_d = ST.build_decode_cell(cfg, shape_d, mesh)
with mesh:
    compiled_d = ST.lower_cell(cell_d).compile()
out["decode"] = {"ok": True}
print("RESULT " + json.dumps(out))
"""


@pytest.mark.slow
def test_sharded_train_and_decode_compile_and_run():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run(
        [sys.executable, "-c", SCRIPT], env=env, capture_output=True,
        text=True, timeout=600,
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT ")][-1]
    out = json.loads(line[len("RESULT "):])
    assert out["train"]["loss_finite"]
    assert out["train"]["flops"] > 0
    assert out["train"]["collective_wire"] > 0  # grad all-reduce exists
    assert out["decode"]["ok"]


@pytest.mark.slow
def test_dryrun_cell_json_schema(tmp_path):
    """Run the actual dryrun module for one small cell (8 devices) and
    validate the JSON record schema the roofline reader consumes."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    script = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, json
from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.launch import steps as ST, hlo_analysis as HA, rooflines as RL

cfg = get_config("tiny_ssm")
mesh = jax.make_mesh((4, 2), ("data", "model"))
shape = ShapeConfig("t", 64, 8, "train")
cell = ST.build_train_cell(cfg, shape, mesh, microbatches=1, fsdp=False)
with mesh:
    compiled = ST.lower_cell(cell).compile()
st = HA.analyze(compiled.as_text(), 8)
roof = RL.terms(st, cell.cfg, shape, 8)
rec = {"hlo_stats": st.asdict(), "roofline": roof.asdict()}
print("RESULT " + json.dumps(rec))
"""
    proc = subprocess.run(
        [sys.executable, "-c", script], env=env, capture_output=True,
        text=True, timeout=600,
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT ")][-1]
    rec = json.loads(line[len("RESULT "):])
    roof = rec["roofline"]
    for key in ("compute_s", "memory_s", "collective_s", "bottleneck",
                "model_flops_per_chip", "hlo_flops_per_chip",
                "useful_ratio", "roofline_fraction"):
        assert key in roof
    assert roof["bottleneck"] in ("compute", "memory", "collective")
    assert rec["hlo_stats"]["flops"] > 0

"""Sharded EBFT calibration walk: numerical parity with the single-device
path, collective/memory accounting, and the sharded checkpoint round-trip.

Needs >1 device, so everything runs in a subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count=8 (the main test process
keeps the default single device) — same pattern as test_distribution.py.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax
import numpy as np

from repro.configs import get_config
from repro.models.model import build
from repro.core.masks import prune
from repro.core import ebft
from repro.launch.mesh import make_ebft_plan

cfg = get_config("tiny_dense")
model = build(cfg)
params = model.init(jax.random.PRNGKey(0))
rng = np.random.default_rng(0)
calib = rng.integers(0, cfg.vocab_size, size=(16, 32)).astype(np.int32)
masks, pruned = prune(model, params, calib, method="magnitude", sparsity=0.5)

base = dict(lr=1e-2, epochs=2, microbatch=8, patience=2)
out = {"meshes": {}}

# live-byte accounting is obs-gated; run under a (console-less) obs run
from repro.obs.run import start_run
run = start_run("mesh_test", config="tiny_dense", console=False)

_, rep_single = ebft.finetune(model, params, pruned, masks, calib,
                              ebft.EBFTConfig(**base))

for mesh_data, mesh_model in [(8, 1), (4, 2)]:
    plan = make_ebft_plan(mesh_data, mesh_model)
    assert plan.active
    _, rep_mesh = ebft.finetune(model, params, pruned, masks, calib,
                                ebft.EBFTConfig(**base, mesh_plan=plan))
    assert len(rep_single) == len(rep_mesh)
    parity = True
    for rs, rm in zip(rep_single, rep_mesh):
        assert rs.path == rm.path == "fused", (rs.path, rm.path)
        parity = parity and np.allclose(rs.history, rm.history,
                                        rtol=2e-3, atol=1e-5)
    r0 = rep_mesh[0]
    out["meshes"][f"{mesh_data}x{mesh_model}"] = {
        "parity": bool(parity),
        "device_dispatches": r0.device_dispatches,
        "dispatches": r0.dispatches,
        "devices": plan.device_count,
        "collective_bytes": r0.collective_bytes,
        "live_bytes": r0.live_bytes,
        "live_bytes_per_shard": r0.live_bytes_per_shard,
    }

run.finish()

# sharded checkpoint round-trip: save from a (4, 2) mesh, restore both
# onto the same layout (template-derived shardings) and elastically onto
# a different mesh
from repro.checkpoint import ckpt as CK

plan = make_ebft_plan(4, 2)
bp = model.get_block(params, 0)
bp_sharded = plan.put_block(bp)
ckdir = os.environ["MESH_CKPT_DIR"]
CK.save(ckdir, {"block": bp_sharded}, step=1, async_write=False)
restored = CK.restore(ckdir, {"block": bp_sharded})
same_layout = all(
    a.sharding == b.sharding and np.allclose(np.asarray(a), np.asarray(b))
    for a, b in zip(jax.tree.leaves(restored), jax.tree.leaves(bp_sharded))
)
plan2 = make_ebft_plan(8, 1)
restored2 = CK.restore(ckdir, {"block": plan2.put_block(bp)})
elastic_ok = all(
    np.allclose(np.asarray(a), np.asarray(b))
    for a, b in zip(jax.tree.leaves(restored2), jax.tree.leaves(bp))
)
out["ckpt"] = {"same_layout": bool(same_layout), "elastic": bool(elastic_ok)}
print("RESULT " + json.dumps(out))
"""


@pytest.mark.slow
def test_mesh_parity_accounting_and_ckpt(tmp_path):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env["MESH_CKPT_DIR"] = str(tmp_path / "ck")
    proc = subprocess.run(
        [sys.executable, "-c", SCRIPT], env=env, capture_output=True,
        text=True, timeout=600,
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT ")][-1]
    out = json.loads(line[len("RESULT "):])

    for key, rec in out["meshes"].items():
        # the sharded fused loop must reproduce the single-device loss
        # trajectory (GSPMD psum == the unsharded batched gradient)
        assert rec["parity"], f"mesh {key} diverged from single-device"
        # one SPMD launch per host dispatch, replicated across devices
        assert rec["device_dispatches"] == rec["dispatches"] * rec["devices"]
        # the gradient all-reduce is real wire traffic
        assert rec["collective_bytes"] > 0

    # model-axis sharding actually splits the live block; pure data
    # parallelism replicates it
    assert out["meshes"]["4x2"]["live_bytes_per_shard"] < \
        out["meshes"]["4x2"]["live_bytes"]
    assert out["meshes"]["8x1"]["live_bytes_per_shard"] == \
        out["meshes"]["8x1"]["live_bytes"]

    assert out["ckpt"]["same_layout"]
    assert out["ckpt"]["elastic"]

"""Import shim for ``hypothesis``.

The CI image carries hypothesis; some dev containers do not (and installing
packages is not allowed there). Property-based tests import ``given`` /
``settings`` / ``strategies`` from this module instead of from hypothesis
directly: when the real library is present they are re-exported unchanged,
otherwise stand-ins are provided that mark each ``@given`` test as skipped
with an explicit environmental reason — the rest of the module (plain
example-based tests) still runs.
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised only without hypothesis
    HAVE_HYPOTHESIS = False

    import pytest

    _REASON = (
        "hypothesis not installed in this environment "
        "(no network installs available); property test skipped"
    )

    class _Strategy:
        """Opaque placeholder for a hypothesis strategy."""

        def __call__(self, *args, **kwargs):
            return self

        def __getattr__(self, name):
            return self

    class _Strategies:
        def composite(self, fn):
            return lambda *a, **k: _Strategy()

        def __getattr__(self, name):
            return lambda *a, **k: _Strategy()

    strategies = _Strategies()

    def settings(*args, **kwargs):
        return lambda fn: fn

    def given(*args, **kwargs):
        def deco(fn):
            def stub():
                pytest.skip(_REASON)

            stub.__name__ = fn.__name__
            stub.__doc__ = fn.__doc__
            return stub

        return deco

"""Serving layer: batched generation and continuous batching scheduler."""
from __future__ import annotations

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.model import build
from repro.serving.decode import Request, Server


@pytest.fixture(scope="module")
def served():
    cfg = get_config("tiny_dense")
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return model, params


def test_generate_batched_shapes(served):
    model, params = served
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, 500, size=(16,)).astype(np.int32) for _ in range(3)]
    server = Server(model, params, batch_size=4, max_len=64)
    outs = server.generate(prompts, max_new=8)
    assert len(outs) == 3 and all(len(o) == 8 for o in outs)


def test_generate_deterministic_greedy(served):
    model, params = served
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, 500, size=(12,)).astype(np.int32)]
    server = Server(model, params, batch_size=2, max_len=64)
    a = server.generate(prompts, max_new=6)
    b = server.generate(prompts, max_new=6)
    assert a == b


def test_continuous_batching_serves_all(served):
    model, params = served
    rng = np.random.default_rng(2)
    reqs = [
        Request(uid=i, prompt=rng.integers(0, 500, size=(10,)).astype(np.int32),
                max_new=4 + (i % 3))
        for i in range(7)
    ]
    server = Server(model, params, batch_size=3, max_len=64)
    results = server.serve(reqs)
    assert sorted(results) == list(range(7))
    for i, out in results.items():
        assert len(out) == 4 + (i % 3)


def test_sparse_params_serve_unchanged(served):
    """EBFT/pruned weights drop into the serving path (same pytree)."""
    from repro.core.masks import prune
    from repro.data.tokens import CorpusConfig, SyntheticCorpus, calibration_set

    model, params = served
    corpus = SyntheticCorpus(CorpusConfig(vocab_size=model.cfg.vocab_size))
    calib = calibration_set(corpus, 8, 32)
    _, pruned = prune(model, params, calib, method="wanda", sparsity=0.5)
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, 500, size=(8,)).astype(np.int32)]
    outs = Server(model, pruned, batch_size=1, max_len=32).generate(prompts, max_new=4)
    assert len(outs[0]) == 4

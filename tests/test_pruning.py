"""Pruning methods: target sparsity is hit, the mask/param invariant holds,
N:M patterns verify group-wise, FLAP produces structured (whole-unit) masks."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.masks import prune
from repro.models.model import build
from repro.sparsity import sparse_params as SP

METHODS = ["magnitude", "wanda", "sparsegpt", "dsnot"]


@pytest.fixture(scope="module")
def dense(trained_tiny_dense):
    return trained_tiny_dense


@pytest.mark.parametrize("method", METHODS)
def test_unstructured_hits_target_sparsity(dense, tiny_calib, method):
    model, params = dense
    masks, pruned = prune(model, params, tiny_calib, method=method, sparsity=0.6)
    s = SP.sparsity_of(masks, params)
    assert abs(s - 0.6) < 0.02, f"{method}: sparsity {s}"


@pytest.mark.parametrize("method", METHODS)
def test_pruned_params_equal_masked_params(dense, tiny_calib, method):
    """Invariant every consumer (EBFT, serving, nm compressor) relies on:
    pruned weights are exactly zero where mask==0."""
    model, params = dense
    masks, pruned = prune(model, params, tiny_calib, method=method, sparsity=0.5)

    def check(path, p, m):
        if SP.is_prunable(path, p):
            live = np.asarray(m) > 0
            w = np.asarray(p, np.float32)
            assert np.all(w[~live] == 0.0)
        return p

    jax.tree_util.tree_map_with_path(check, pruned, masks)


@pytest.mark.parametrize("method", ["magnitude", "wanda", "sparsegpt"])
@pytest.mark.parametrize("pattern", [(2, 4), (4, 8)])
def test_nm_pattern_group_invariant(dense, tiny_calib, method, pattern):
    """Every M-group along the reduction axis keeps exactly N weights."""
    model, params = dense
    n, m_ = pattern
    masks, _ = prune(model, params, tiny_calib, method=method,
                     sparsity=n / m_, pattern=pattern)

    def check(path, p, m):
        if SP.is_prunable(path, p):
            name = SP._path_names(path)[-1]
            # model-level masks carry the stacked L axis -> stack-aware view
            mat = np.asarray(SP.to_matrix_stacked(name, m)[0])
            R, O = mat.shape[-2:]
            mat = mat.reshape(-1, R, O)
            if R % m_ == 0 and name != "conv_w":
                g = mat.reshape(mat.shape[0], R // m_, m_, O).sum(axis=2)
                assert np.all(g == n), f"{name}: N:M group violated"
        return p

    jax.tree_util.tree_map_with_path(check, params, masks)


def test_wanda_uses_activation_norms(dense, tiny_calib):
    """Wanda must differ from pure magnitude when activations are skewed
    (they are, for a trained model): masks should not be identical."""
    model, params = dense
    masks_w, _ = prune(model, params, tiny_calib, method="wanda", sparsity=0.5)
    masks_m, _ = prune(model, params, None, method="magnitude", sparsity=0.5)
    same = all(
        np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(masks_w), jax.tree.leaves(masks_m))
    )
    assert not same


def test_sparsegpt_updates_surviving_weights(dense, tiny_calib):
    """SparseGPT compensates: surviving weights differ from the dense ones
    (unlike Wanda which only zeroes)."""
    model, params = dense
    masks, pruned = prune(model, params, tiny_calib, method="sparsegpt", sparsity=0.5)

    changed = []

    def check(path, p0, p1, m):
        if SP.is_prunable(path, p0):
            live = np.asarray(m) > 0
            a = np.asarray(p0, np.float32)[live]
            b = np.asarray(p1, np.float32)[live]
            changed.append(not np.allclose(a, b))
        return p0

    jax.tree_util.tree_map_with_path(check, params, pruned, masks)
    assert any(changed)


def test_dsnot_preserves_sparsity_while_reselecting(dense, tiny_calib):
    model, params = dense
    masks_w, _ = prune(model, params, tiny_calib, method="wanda", sparsity=0.6)
    masks_d, _ = prune(model, params, tiny_calib, method="dsnot", sparsity=0.6,
                       dsnot_init="wanda")
    s_w = SP.sparsity_of(masks_w, params)
    s_d = SP.sparsity_of(masks_d, params)
    assert abs(s_w - s_d) < 0.02
    # and it actually moved some masks
    moved = any(
        not np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(masks_w), jax.tree.leaves(masks_d))
    )
    assert moved


def test_flap_masks_are_structured(dense, tiny_calib):
    """FLAP removes whole units: each mask's canonical view must be
    constant along the reduction axis (column removal)."""
    model, params = dense
    masks, _ = prune(model, params, tiny_calib, method="flap", sparsity=0.4)

    def check(path, p, m):
        if SP.is_prunable(path, p):
            name = SP._path_names(path)[-1]
            if name in ("w_up", "w_gate", "wq", "wk", "wv"):
                mat = np.asarray(SP.to_matrix_stacked(name, m)[0])  # (L, R, O)
                # every output column is all-0 or all-1 per layer slice
                col = mat.mean(axis=-2)
                assert np.all((col == 0) | (col == 1)), f"{name} not structured"
        return p

    jax.tree_util.tree_map_with_path(check, params, masks)


def test_pruning_moe_respects_router_protection(tiny_calib):
    cfg = get_config("tiny_moe")
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    masks, pruned = prune(model, params, tiny_calib, method="magnitude", sparsity=0.8)

    def check(path, p, m):
        names = SP._path_names(path)
        if "router" in names:
            assert getattr(m, "ndim", 0) == 0 or float(jnp.min(m)) == 1.0
        return p

    jax.tree_util.tree_map_with_path(check, params, masks)

"""Per-kernel correctness: Pallas (interpret=True on CPU) vs the pure-jnp
ref.py oracle, swept over shapes and dtypes (assignment requirement)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import ops as FA
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.masked_matmul import ops as MM
from repro.kernels.masked_matmul.ref import masked_matmul_ref
from repro.kernels.nm_spmm import ops as NM
from repro.kernels.nm_spmm.ref import nm_spmm_ref
from repro.sparsity.sparse_params import nm_compress, nm_decompress, nm_mask

RNG = np.random.default_rng(0)


def _rand(shape, dtype):
    x = RNG.normal(size=shape).astype(np.float32)
    return jnp.asarray(x, dtype=dtype)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# masked matmul: fused (W (x) M) . X
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "m,k,n", [(8, 128, 128), (16, 256, 512), (128, 384, 256), (1, 128, 640)]
)
def test_masked_matmul_matches_ref(m, k, n, dtype):
    x = _rand((m, k), dtype)
    w = _rand((k, n), dtype)
    mask = jnp.asarray(RNG.random((k, n)) > 0.5)
    out = MM.masked_matmul(x, w, mask, interpret=True)
    ref = masked_matmul_ref(x, w, mask)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), **_tol(dtype)
    )


def test_masked_matmul_all_masked_is_zero():
    x = _rand((8, 128), jnp.float32)
    w = _rand((128, 128), jnp.float32)
    out = MM.masked_matmul(x, w, jnp.zeros((128, 128), bool), interpret=True)
    assert float(jnp.abs(out).max()) == 0.0


# ---------------------------------------------------------------------------
# N:M compressed matmul
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("n,m", [(2, 4), (4, 8), (1, 4)])
@pytest.mark.parametrize("R,O,B", [(256, 512, 8), (128, 128, 16)])
def test_nm_spmm_matches_dense(n, m, R, O, B, dtype):
    w = _rand((R, O), dtype)
    mask = nm_mask(w.astype(jnp.float32), n, m)
    vals, idx = nm_compress((w * mask.astype(dtype)).astype(dtype), mask, n, m)
    x = _rand((B, R), dtype)
    out = NM.nm_spmm(x, vals, idx, n=n, m=m, interpret=True)
    dense = (x.astype(jnp.float32) @ (w * mask.astype(dtype)).astype(jnp.float32))
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(dense), **_tol(dtype)
    )


def test_nm_spmm_matches_ref_oracle():
    w = _rand((256, 256), jnp.float32)
    mask = nm_mask(w, 2, 4)
    vals, idx = nm_compress(w * mask, mask, 2, 4)
    x = _rand((4, 256), jnp.float32)
    out = NM.nm_spmm(x, vals, idx, n=2, m=4, interpret=True)
    ref = nm_spmm_ref(x, vals, idx, n=2, m=4)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5)


def test_nm_compress_roundtrip_exact():
    w = _rand((512, 128), jnp.float32)
    mask = nm_mask(w, 2, 4)
    vals, idx = nm_compress(w * mask, mask, 2, 4)
    dense = nm_decompress(vals, idx, 2, 4)
    np.testing.assert_array_equal(np.asarray(dense), np.asarray(w * mask))


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("bh,s,hd", [(4, 256, 64), (2, 512, 128), (8, 128, 64)])
def test_flash_attention_matches_ref(bh, s, hd, causal, dtype):
    q = _rand((bh, s, hd), dtype)
    k = _rand((bh, s, hd), dtype)
    v = _rand((bh, s, hd), dtype)
    out = FA.flash_attention(q, k, v, causal=causal, interpret=True)
    ref = flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        rtol=3e-2 if dtype == jnp.bfloat16 else 1e-4,
        atol=3e-2 if dtype == jnp.bfloat16 else 1e-4,
    )


def test_flash_attention_bshd_gqa_layout():
    B, S, H, hd = 2, 128, 4, 64
    q = _rand((B, S, H, hd), jnp.float32)
    out = FA.flash_attention_bshd(q, q, q, causal=True, interpret=True)
    assert out.shape == (B, S, H, hd)
    # against the model-layer chunked implementation (same math)
    from repro.models.layers import attend
    ref = attend(q, q, q, causal=True, impl="chunked", chunk=64)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-4, atol=1e-4)


def test_flash_attention_q_offset_decode_semantics():
    """A 1-token query with q_offset=S must equal full-cache attention."""
    BH, S, hd = 2, 128, 64
    k = _rand((BH, S, hd), jnp.float32)
    v = _rand((BH, S, hd), jnp.float32)
    q = _rand((BH, 1, hd), jnp.float32)
    out = FA.flash_attention(q, k, v, causal=True, q_offset=S - 1, interpret=True)
    ref = flash_attention_ref(q, k, v, causal=True, q_offset=S - 1)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# parity across non-default tile grids — every plan the autotuner can pick
# must compute the same numbers (clamped requests included: a tile larger
# than its dim clamps to the ragged edge and still has to be exact)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("tiles", [
    dict(bm=32, bk=64, bn=64),     # multi-step grid on every axis
    dict(bm=256, bk=128, bn=512),  # bm clamps 256->64, bn = whole N
    dict(bm=64, bk=256, bn=128),   # whole-K tile (single k step)
])
def test_masked_matmul_parity_across_tile_grids(tiles):
    M, K, N = 64, 256, 512
    x = _rand((M, K), jnp.float32)
    w = _rand((K, N), jnp.float32)
    mask = jnp.asarray(RNG.random((K, N)) > 0.5)
    out = MM.masked_matmul(x, w, mask, interpret=True, **tiles)
    ref = masked_matmul_ref(x, w, mask)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("tiles", [
    dict(bm=8, bk=64, bn=64),
    dict(bm=128, bk=256, bn=128),  # bm clamps 128->16, whole-K tile
    dict(bm=16, bk=32, bn=32),
])
def test_nm_spmm_parity_across_tile_grids(tiles):
    B, R, O = 16, 256, 128
    w = _rand((R, O), jnp.float32)
    mask = nm_mask(w, 2, 4)
    vals, idx = nm_compress(w * mask, mask, 2, 4)
    x = _rand((B, R), jnp.float32)
    out = NM.nm_spmm(x, vals, idx, n=2, m=4, interpret=True, **tiles)
    ref = nm_spmm_ref(x, vals, idx, n=2, m=4)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("tiles", [
    dict(bq=32, bk=64),
    dict(bq=256, bk=32),   # bq clamps 256->128 (whole Sq in one tile)
    dict(bq=64, bk=128),   # whole-Sk tile (single j step)
])
def test_flash_attention_parity_across_tile_grids(tiles, causal):
    BH, S, hd = 2, 128, 64
    q = _rand((BH, S, hd), jnp.float32)
    k = _rand((BH, S, hd), jnp.float32)
    v = _rand((BH, S, hd), jnp.float32)
    out = FA.flash_attention(q, k, v, causal=causal, interpret=True, **tiles)
    ref = flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# the uniform dispatch surface (repro.kernels.dispatch)
# ---------------------------------------------------------------------------
def test_dispatch_registry_names():
    from repro import kernels

    assert set(kernels.names()) == {
        "masked_matmul", "nm_spmm", "flash_attention"
    }
    with pytest.raises(KeyError, match="unknown kernel"):
        kernels.dispatch("nope")


def test_dispatch_masked_matmul_matches_direct_call():
    from repro import kernels

    x = _rand((8, 128), jnp.float32)
    w = _rand((128, 128), jnp.float32)
    mask = jnp.asarray(RNG.random((128, 128)) > 0.5)
    out = kernels.dispatch("masked_matmul", x, w, mask, interpret=True)
    ref = MM.masked_matmul(x, w, mask, interpret=True)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_dispatch_flash_layout_param():
    from repro import kernels

    B, S, H, hd = 2, 64, 4, 64
    q = _rand((B, S, H, hd), jnp.float32)
    out = kernels.dispatch("flash_attention", q, q, q, layout="bshd",
                           causal=True, interpret=True)
    ref = FA.flash_attention_bshd(q, q, q, causal=True, interpret=True)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_package_aliases_survive_submodule_import():
    """Importing repro.kernels.X.ops rebinds the package attribute 'X' to
    the subpackage module; dispatch() must restore the callable alias."""
    from repro import kernels

    kernels.dispatch(
        "masked_matmul",
        _rand((8, 128), jnp.float32), _rand((128, 128), jnp.float32),
        jnp.ones((128, 128), bool), interpret=True,
    )
    assert callable(kernels.masked_matmul)
    assert callable(kernels.nm_spmm)
    assert callable(kernels.flash_attention)

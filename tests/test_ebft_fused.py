"""The fused EBFT hot path (core/ebft.py + the stacked dual-stream walk).

1. Fused and legacy paths produce the same loss histories / reports —
   the fusion is a dispatch-count optimization, not a semantic change.
2. Buffer donation is safe: caller-held params survive the donated
   dispatches (including the hybrid shared block, whose leaves come back
   by reference from ``get_block``).
3. The device-side plateau predicate matches the host predicate exactly,
   including the degenerate cases.
4. Ragged microbatch shapes fall back to the legacy per-step loop.
5. The dispatch budget holds: one tune dispatch + one host sync per
   fused block (walk advances add two more — docs/PERF.md).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ebft
from repro.core.evaluate import perplexity
from repro.core.masks import prune
from repro.optim.schedules import plateau_early_stop, plateau_early_stop_device
from repro.sparsity import sparse_params as SP


def _cfg(**kw):
    base = dict(lr=1e-2, epochs=4, microbatch=8, patience=2)
    base.update(kw)
    return ebft.EBFTConfig(**base)


@pytest.fixture(scope="module")
def pruned_setup(trained_tiny_dense, tiny_calib):
    model, params = trained_tiny_dense
    masks, pruned = prune(model, params, tiny_calib, method="wanda",
                          sparsity=0.7)
    return model, params, masks, pruned


@pytest.fixture(scope="module")
def both_paths(pruned_setup, tiny_calib):
    model, params, masks, pruned = pruned_setup
    calib = tiny_calib[:16]
    fused = ebft.finetune(model, params, pruned, masks, calib,
                          _cfg(fused_epochs=True))
    legacy = ebft.finetune(model, params, pruned, masks, calib,
                           _cfg(fused_epochs=False))
    return fused, legacy


# ---------------------------------------------------------------------------
# 1. parity
# ---------------------------------------------------------------------------
def test_fused_vs_legacy_loss_history_parity(both_paths):
    (_, rep_f), (_, rep_l) = both_paths
    assert len(rep_f) == len(rep_l) > 0
    for rf, rl in zip(rep_f, rep_l):
        assert rf.path == "fused" and rl.path == "legacy"
        assert rf.epochs_run == rl.epochs_run
        assert rf.early_stop == rl.early_stop
        assert len(rf.history) == len(rl.history)
        np.testing.assert_allclose(rf.history, rl.history, atol=1e-6,
                                   err_msg=f"block {rf.index}")
        assert abs(rf.loss_after - rl.loss_after) < 1e-6


def test_fused_vs_legacy_params_parity(both_paths):
    (tuned_f, _), (tuned_l, _) = both_paths
    for a, b in zip(jax.tree.leaves(tuned_f), jax.tree.leaves(tuned_l)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-5)


def test_prefetch_depth_does_not_change_results(pruned_setup, tiny_calib):
    model, params, masks, pruned = pruned_setup
    calib = tiny_calib[:16]
    _, rep0 = ebft.finetune(model, params, pruned, masks, calib,
                            _cfg(epochs=2, prefetch_depth=0))
    _, rep2 = ebft.finetune(model, params, pruned, masks, calib,
                            _cfg(epochs=2, prefetch_depth=2))
    for a, b in zip(rep0, rep2):
        np.testing.assert_allclose(a.history, b.history, atol=1e-6)


# ---------------------------------------------------------------------------
# 2. donation safety
# ---------------------------------------------------------------------------
def test_donation_actually_happens_on_this_backend():
    """The fused path relies on donate_argnums; prove the backend honors
    it (otherwise the live-block-bytes claim silently doubles)."""
    f = jax.jit(lambda x: x + 1.0, donate_argnums=(0,))
    x = jnp.ones((128,))
    y = f(x)
    assert x.is_deleted()
    assert float(y[0]) == 2.0


def test_fused_does_not_corrupt_caller_inputs(pruned_setup, tiny_calib,
                                              tiny_eval):
    """No use-after-donate: the caller's pruned params and masks must
    survive finetune, and a second identical run must reproduce the
    first (corrupted inputs would diverge)."""
    model, params, masks, pruned = pruned_setup
    calib = tiny_calib[:16]
    tuned1, rep1 = ebft.finetune(model, params, pruned, masks, calib,
                                 _cfg(epochs=2))
    for leaf in jax.tree.leaves((pruned, masks, params, tuned1)):
        assert not leaf.is_deleted()
    assert np.isfinite(perplexity(model, tuned1, tiny_eval))
    tuned2, rep2 = ebft.finetune(model, params, pruned, masks, calib,
                                 _cfg(epochs=2))
    for a, b in zip(rep1, rep2):
        np.testing.assert_allclose(a.history, b.history, atol=0)


def test_fused_hybrid_shared_block_survives_donation(tiny_calib):
    """tiny_hybrid's shared block comes back from get_block by reference;
    the driver must copy before the donated dispatch or `result` is
    freed out from under the caller."""
    from repro.configs import get_config
    from repro.models.model import build

    cfg = get_config("tiny_hybrid")
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    calib = tiny_calib[:8]
    masks, pruned = prune(model, params, calib, method="magnitude",
                          sparsity=0.5)
    tuned, reports = ebft.finetune(
        model, params, pruned, masks, calib,
        _cfg(lr=1e-3, epochs=2, microbatch=4),
    )
    for leaf in jax.tree.leaves(tuned):
        assert not leaf.is_deleted()
    for r in reports:
        assert np.isfinite(r.loss_after)

    def check(path, w, m):
        if SP.is_prunable(path, w):
            dead = np.asarray(m) == 0
            assert np.all(np.asarray(w, np.float32)[dead] == 0.0)
        return w

    jax.tree_util.tree_map_with_path(check, tuned, masks)


# ---------------------------------------------------------------------------
# 3. plateau predicate: edge cases + host/device equivalence
# ---------------------------------------------------------------------------
def test_plateau_early_stop_edge_cases():
    assert plateau_early_stop([], 3) is False
    assert plateau_early_stop([1.0], 3) is False
    assert plateau_early_stop([1.0, 0.9], 5) is False       # patience > len
    assert plateau_early_stop([1.0, 1.0, 1.0], 0) is False  # patience <= 0
    assert plateau_early_stop([1.0, 1.0, 1.0], -2) is False
    # genuine plateau fires; genuine improvement does not
    assert plateau_early_stop([1.0, 0.5, 0.5, 0.5], 2)
    assert not plateau_early_stop([1.0, 0.8, 0.6, 0.4], 2)


@pytest.mark.parametrize("patience", [0, 1, 2, 3, 7])
def test_plateau_device_matches_host(patience):
    histories = [
        [],
        [1.0],
        [1.0, 0.9],
        [1.0, 0.5, 0.5, 0.5],
        [1.0, 0.8, 0.6, 0.4],
        [1.0, 0.99999, 0.99998, 0.99997],
        [2.0, 1.0, 1.5, 1.4, 1.45],
        [1.0, 0.5, 0.4, 0.41, 0.42, 0.43],
    ]
    buf_len = 8
    for h in histories:
        host = plateau_early_stop(h, patience, 1e-3)
        buf = np.full((buf_len,), np.inf, np.float32)
        buf[: len(h)] = h
        dev = plateau_early_stop_device(
            jnp.asarray(buf), len(h), patience, 1e-3
        )
        assert bool(dev) == host, (h, patience)


# ---------------------------------------------------------------------------
# 4. ragged fallback + 5. dispatch budget
# ---------------------------------------------------------------------------
def test_ragged_microbatches_fall_back_to_legacy(pruned_setup, tiny_calib):
    model, params, masks, pruned = pruned_setup
    # 12 samples at microbatch 8 -> microbatches of 8 and 4 (ragged)
    calib = tiny_calib[:12]
    _, reports = ebft.finetune(model, params, pruned, masks, calib,
                               _cfg(epochs=2))
    assert all(r.path == "legacy" for r in reports)
    for r in reports:
        assert np.isfinite(r.loss_after)


def test_fused_dispatch_budget(both_paths):
    """Fused: 1 tune dispatch + 1 host sync per block. With the walk's
    two stream advances that is 3 <= epochs + 2 total (the CI gate)."""
    (_, rep_f), (_, rep_l) = both_paths
    for r in rep_f:
        assert r.dispatches == 1
        assert r.host_syncs == 1
        assert r.dispatches + 2 <= _cfg().epochs + 2
    # and the legacy path really is per-microbatch/per-epoch dispatch
    for r in rep_l:
        assert r.dispatches > r.epochs_run


def test_fused_stacking_helper_rejects_ragged():
    a = (jnp.ones((2, 3)), jnp.zeros((2,)))
    b = (jnp.ones((2, 3)), jnp.zeros((2,)))
    ragged = (jnp.ones((1, 3)), jnp.zeros((1,)))
    stacked = ebft._stack_microbatches([a, b])
    assert stacked[0].shape == (2, 2, 3)
    assert ebft._stack_microbatches([a, ragged]) is None
    assert ebft._stack_microbatches([]) is None

"""Kernel autotuner: candidate generation, the measured search, the
persistent cache (modes, staleness, corruption), wrapper integration,
the tuning_cache analysis pass, and the warm-run artifact gate."""
from __future__ import annotations

import json
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import tuning
from repro.kernels.validation import VMEM_BUDGET_BYTES

F32 = "float32"
MM_DTYPES = {"x": F32, "w": F32}


@pytest.fixture(autouse=True)
def fresh_tuner(tmp_path):
    """Every test gets a clean tuner pointed at its own cache file."""
    tuning._reset_for_tests()
    tuning.configure(path=str(tmp_path / "cache.json"))
    yield
    tuning._reset_for_tests()


def _cache_path() -> str:
    return tuning.state()["path"]


def _write_cache(entries, schema=tuning.SCHEMA) -> str:
    path = _cache_path()
    with open(path, "w") as f:
        json.dump({"schema": schema, "code_rev": tuning.code_rev(),
                   "entries": entries}, f)
    return path


def _entry(dims=None, tiles=None, **over):
    base = {
        "kernel": "masked_matmul",
        "dims": dims or {"M": 64, "K": 128, "N": 128},
        "dtypes": dict(MM_DTYPES),
        "params": {},
        "backend": "cpu",
        "device_kind": "cpu",
        "code_rev": tuning.code_rev(),
        "tiles": tiles if tiles is not None else {"bm": 64, "bk": 128,
                                                  "bn": 128},
        "measured_s": {"default": 1.0, "best": 1.0},
        "candidates": 1,
    }
    base.update(over)
    return base


# ---------------------------------------------------------------------------
# candidate generation
# ---------------------------------------------------------------------------
def test_candidates_default_first_unique_and_valid():
    dims = {"M": 64, "K": 256, "N": 128}
    cands = tuning.candidate_tiles("masked_matmul", dims, MM_DTYPES)
    assert cands, "at least the default plan must be admitted"
    # candidate 0 is the (clamped) default plan
    default = tuning.build_plan("masked_matmul", dims, MM_DTYPES, {}, {})
    assert cands[0] == default.tiles
    seen = set()
    for tiles in cands:
        plan = tuning.build_plan("masked_matmul", dims, MM_DTYPES, {}, tiles)
        assert plan.vmem_bytes() <= VMEM_BUDGET_BYTES
        key = tuple(sorted(plan.tiles.items()))
        assert key not in seen, "clamp-duplicates must collapse"
        seen.add(key)


def test_candidates_respect_interpret_grid_cap():
    dims = {"M": 2048, "K": 2048, "N": 2048}
    cands = tuning.candidate_tiles("masked_matmul", dims, MM_DTYPES,
                                   interpret=True)
    for tiles in cands:
        plan = tuning.build_plan("masked_matmul", dims, MM_DTYPES, {}, tiles)
        assert int(np.prod(plan.grid)) <= tuning.INTERPRET_GRID_CAP


def test_candidates_respect_nm_group_alignment():
    dims = {"M": 32, "K": 256, "N": 128}
    params = {"n": 2, "m": 4}
    cands = tuning.candidate_tiles("nm_spmm", dims, {"x": F32, "v": F32},
                                   params)
    assert cands
    assert all(t["bk"] % 4 == 0 for t in cands)


def test_build_plan_rejects_unknown_kernel_and_knobs():
    with pytest.raises(ValueError, match="unknown kernel"):
        tuning.build_plan("conv", {}, {}, {}, {})
    with pytest.raises(ValueError, match="unknown tile knobs"):
        tuning.build_plan("masked_matmul", {"M": 8, "K": 128, "N": 128},
                          MM_DTYPES, {}, {"bz": 32})


# ---------------------------------------------------------------------------
# cache key
# ---------------------------------------------------------------------------
def test_cache_key_is_insertion_order_insensitive():
    a = tuning.cache_key("mm", {"M": 1, "K": 2}, {"x": F32}, {"p": 3},
                         "cpu", "cpu", "rev")
    b = tuning.cache_key("mm", {"K": 2, "M": 1}, {"x": F32}, {"p": 3},
                         "cpu", "cpu", "rev")
    assert a == b
    assert a != tuning.cache_key("mm", {"M": 1, "K": 2}, {"x": F32},
                                 {"p": 3}, "cpu", "cpu", "other-rev")


def test_code_rev_is_stable_within_a_process():
    assert tuning.code_rev() == tuning.code_rev()
    assert len(tuning.code_rev()) == 12


# ---------------------------------------------------------------------------
# measured search
# ---------------------------------------------------------------------------
def test_search_measures_default_inside_the_sweep():
    entry = tuning.search("masked_matmul", {"M": 16, "K": 128, "N": 128},
                          MM_DTYPES, interpret=True, reps=1,
                          max_candidates=3)
    ms = entry["measured_s"]
    # the acceptance ordering holds by construction, never by luck
    assert ms["best"] <= ms["default"]
    assert entry["code_rev"] == tuning.code_rev()
    assert entry["candidates"] >= 1
    tuning.build_plan(entry["kernel"], entry["dims"], entry["dtypes"],
                      entry["params"], entry["tiles"])  # winner is valid


def test_search_runs_all_three_kernels():
    for kernel, dims, dtypes, params in [
        ("nm_spmm", {"M": 8, "K": 128, "N": 128}, {"x": F32, "v": F32},
         {"n": 2, "m": 4}),
        ("flash_attention", {"BH": 2, "Sq": 64, "Sk": 64, "d": 64},
         {"q": F32}, {"causal": True}),
    ]:
        entry = tuning.search(kernel, dims, dtypes, params,
                              interpret=True, reps=1, max_candidates=2)
        assert entry["measured_s"]["best"] <= entry["measured_s"]["default"]


# ---------------------------------------------------------------------------
# resolution modes + persistence
# ---------------------------------------------------------------------------
def test_mode_off_returns_defaults_and_counts_nothing():
    tiles, source = tuning.resolve("masked_matmul",
                                   {"M": 16, "K": 128, "N": 128}, MM_DTYPES)
    assert (tiles, source) == ({}, None)
    assert tuning.stats() == {"hits": 0, "misses": 0, "searches": 0,
                              "search_s": 0.0}


def test_mode_cache_miss_is_free_and_writes_nothing():
    tuning.configure(mode="cache")
    tiles, source = tuning.resolve("masked_matmul",
                                   {"M": 16, "K": 128, "N": 128}, MM_DTYPES)
    assert (tiles, source) == ({}, "default")
    assert tuning.stats()["misses"] == 1
    assert not os.path.exists(_cache_path())


def test_mode_search_persists_and_later_processes_hit():
    tuning.configure(mode="search")
    dims = {"M": 16, "K": 128, "N": 128}
    tiles, source = tuning.resolve("masked_matmul", dims, MM_DTYPES,
                                   interpret=True)
    assert source == "search"
    assert tuning.stats()["searches"] == 1
    assert tuning.stats()["search_s"] > 0

    with open(_cache_path()) as f:
        payload = json.load(f)
    assert payload["schema"] == tuning.SCHEMA
    assert len(payload["entries"]) == 1

    # a fresh process (state reset, same path) in cache mode hits
    path = _cache_path()
    tuning._reset_for_tests(mode="cache")
    tuning.configure(path=path)
    tiles2, source2 = tuning.resolve("masked_matmul", dims, MM_DTYPES,
                                     interpret=True)
    assert source2 == "cache" and tiles2 == tiles
    assert tuning.stats() == {"hits": 1, "misses": 0, "searches": 0,
                              "search_s": 0.0}


def test_corrupt_cached_tiles_degrade_to_a_miss():
    tuning.configure(mode="search")
    dims = {"M": 16, "K": 128, "N": 128}
    tuning.resolve("masked_matmul", dims, MM_DTYPES, interpret=True)

    path = _cache_path()
    with open(path) as f:
        payload = json.load(f)
    for entry in payload["entries"].values():
        entry["tiles"] = {"bm": 7, "bk": 128, "bn": 128}  # 16 % 7 != 0
    with open(path, "w") as f:
        json.dump(payload, f)

    tuning._reset_for_tests(mode="cache")
    tuning.configure(path=path)
    tiles, source = tuning.resolve("masked_matmul", dims, MM_DTYPES,
                                   interpret=True)
    assert (tiles, source) == ({}, "default")  # no crash, defaults run
    assert tuning.stats()["misses"] == 1


def test_unknown_schema_or_garbage_file_starts_fresh():
    tuning.configure(mode="cache")
    path = _cache_path()  # capture before any reset (reset restores default)
    _write_cache({"k": _entry()}, schema="repro.kernels.tuning/v999")
    _, source = tuning.resolve("masked_matmul",
                               {"M": 64, "K": 128, "N": 128}, MM_DTYPES)
    assert source == "default"

    tuning._reset_for_tests(mode="cache")
    tuning.configure(path=path)
    with open(path, "w") as f:
        f.write("{not json")
    _, source = tuning.resolve("masked_matmul",
                               {"M": 64, "K": 128, "N": 128}, MM_DTYPES)
    assert source == "default"


def test_store_round_trips_through_load():
    entry = _entry()
    key = tuning.store(entry)
    path = _cache_path()
    tuning._reset_for_tests(mode="cache")
    tuning.configure(path=path)
    tuning._load()
    assert key in tuning._STATE.cache
    # and no stray .tmp files left behind (atomic rename)
    assert [f for f in os.listdir(os.path.dirname(path))
            if f.endswith(".tmp")] == []


# ---------------------------------------------------------------------------
# wrapper integration: the kernel path consults the tuner
# ---------------------------------------------------------------------------
def test_wrapper_searches_then_hits_and_stays_correct():
    from repro.kernels.masked_matmul import ops as MM
    from repro.kernels.masked_matmul.ref import masked_matmul_ref

    tuning.configure(mode="search")
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(16, 128)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(128, 128)), jnp.float32)
    mask = jnp.asarray(rng.random((128, 128)) > 0.5)

    out = MM.masked_matmul(x, w, mask, interpret=True)
    assert tuning.stats()["searches"] == 1
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(masked_matmul_ref(x, w, mask)),
                               rtol=2e-5, atol=2e-5)

    MM.masked_matmul(x, w, mask, interpret=True)  # same shape: cache hit
    assert tuning.stats()["hits"] == 1

    # explicit tiles bypass the tuner entirely
    before = tuning.stats()
    MM.masked_matmul(x, w, mask, interpret=True, bm=16, bk=64, bn=64)
    assert tuning.stats() == before


# ---------------------------------------------------------------------------
# launcher pre-tuning workloads
# ---------------------------------------------------------------------------
def test_ebft_workloads_cover_the_walk_kernels():
    from repro.configs import get_config

    cfg = get_config("tiny_dense")
    work = tuning.ebft_workloads(cfg, tokens=256, seq=32, pattern=(2, 4))
    kinds = {w[0] for w in work}
    assert {"masked_matmul", "flash_attention"} <= kinds
    assert "nm_spmm" in kinds  # tiny_dense dims are 4-aligned
    for kernel, dims, dtypes, params in work:
        assert all(v > 0 for v in dims.values())
        tuning.build_plan(kernel, dims, dtypes, params, {})  # plannable

    # pretune with tuning off resolves every workload to the defaults
    records = tuning.pretune(work, interpret=True)
    assert len(records) == len(work)
    assert all(r["source"] is None and r["tiles"] == {} for r in records)


# ---------------------------------------------------------------------------
# the tuning_cache analysis pass
# ---------------------------------------------------------------------------
def _codes(findings):
    return [f.code for f in findings]


def test_analysis_missing_file_is_clean():
    from repro.analysis.tuning_cache import check_cache

    assert check_cache(_cache_path()) == []


def test_analysis_accepts_a_freshly_searched_cache():
    from repro.analysis.tuning_cache import check_cache

    tuning.configure(mode="search")
    tuning.resolve("masked_matmul", {"M": 16, "K": 128, "N": 128},
                   MM_DTYPES, interpret=True)
    assert check_cache(_cache_path()) == []


def test_analysis_flags_invalid_tiles_as_tun001():
    from repro.analysis.tuning_cache import check_cache

    _write_cache({"k": _entry(tiles={"bm": 7, "bk": 128, "bn": 128})})
    findings = check_cache(_cache_path())
    assert _codes(findings) == ["TUN001"]
    assert findings[0].severity == "error"


def test_analysis_flags_vmem_blowout_as_tun002():
    from repro.analysis.tuning_cache import check_cache

    # valid grid, but 2048^2 f32 tiles: far past the 16 MiB budget —
    # the search can never emit this, so it must be a doctored entry
    entry = _entry(dims={"M": 4096, "K": 4096, "N": 4096},
                   tiles={"bm": 2048, "bk": 2048, "bn": 2048})
    _write_cache({"k": entry})
    assert _codes(check_cache(_cache_path())) == ["TUN002"]


def test_analysis_flags_stale_code_rev_as_tun003_warn():
    from repro.analysis.tuning_cache import check_cache

    _write_cache({"k": _entry(code_rev="000000000000")})
    findings = check_cache(_cache_path())
    assert _codes(findings) == ["TUN003"]
    assert findings[0].severity == "warn"


def test_analysis_flags_malformed_entries_as_tun004():
    from repro.analysis.tuning_cache import check_cache

    entry = _entry()
    del entry["tiles"]
    _write_cache({"a": entry, "b": "not-an-object"})
    assert sorted(_codes(check_cache(_cache_path()))) == ["TUN004", "TUN004"]

    with open(_cache_path(), "w") as f:
        f.write("[]")
    assert _codes(check_cache(_cache_path())) == ["TUN004"]


def test_analysis_pass_registered_in_orchestrator():
    from repro.analysis import PASS_NAMES, run

    assert "tuning_cache" in PASS_NAMES
    _write_cache({"k": _entry(tiles={"bm": 7, "bk": 128, "bn": 128})})
    report = run(config_names=["tiny_dense"], passes=["tuning_cache"],
                 tuning_cache_path=_cache_path())
    assert report.exit_code("error") == 1
    assert [f.code for f in report.findings] == ["TUN001"]


# ---------------------------------------------------------------------------
# the warm-run artifact gate (obs validate --require-cache-hits)
# ---------------------------------------------------------------------------
def _payload(tuning_section):
    out = {
        "manifest": {"schema": "repro.obs/v1", "name": "t",
                     "created_unix": 0.0, "argv": [],
                     "jax_backend": "cpu", "device_count": 1},
        "metrics": {},
        "trace": [],
    }
    if tuning_section is not None:
        out["kernel_tuning"] = tuning_section
    return out


def test_require_cache_hits_passes_on_a_warm_run():
    from repro.obs.run import validate_payload

    warm = {"mode": "cache", "hits": 5, "misses": 0, "searches": 0,
            "search_s": 0.0}
    assert validate_payload(_payload(warm), require_cache_hits=True) == []


@pytest.mark.parametrize("section,needle", [
    (None, "kernel_tuning"),
    ({"hits": 0, "misses": 0, "searches": 0, "search_s": 0.0}, "hits"),
    ({"hits": 3, "misses": 2, "searches": 0, "search_s": 0.0}, "misses"),
    ({"hits": 3, "misses": 0, "searches": 1, "search_s": 0.4}, "searches"),
])
def test_require_cache_hits_rejects_cold_or_missing(section, needle):
    from repro.obs.run import validate_payload

    problems = validate_payload(_payload(section), require_cache_hits=True)
    assert problems and any(needle in p for p in problems)
    # and without the gate the same artifact is fine
    assert validate_payload(_payload(section)) == []

"""End-to-end smoke tests for the CLI launchers (subprocess, tiny configs)."""
from __future__ import annotations

import os
import subprocess
import sys

import pytest

ENV = dict(os.environ, PYTHONPATH=os.path.join(os.path.dirname(__file__), "..", "src"))
ROOT = os.path.join(os.path.dirname(__file__), "..")


def _run(args, timeout=600):
    proc = subprocess.run(
        [sys.executable, "-m"] + args, env=ENV, cwd=ROOT,
        capture_output=True, text=True, timeout=timeout,
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    return proc.stdout


@pytest.mark.slow
def test_train_cli_runs_and_resumes(tmp_path):
    ck = str(tmp_path / "ck")
    out = _run(["repro.launch.train", "--arch", "tiny_dense", "--steps", "12",
                "--batch", "4", "--seq", "32", "--ckpt-dir", ck,
                "--ckpt-every", "6"])
    assert "steps in" in out
    out2 = _run(["repro.launch.train", "--arch", "tiny_dense", "--steps", "16",
                 "--batch", "4", "--seq", "32", "--ckpt-dir", ck])
    assert "resumed from step 12" in out2


@pytest.mark.slow
def test_serve_cli_continuous_batching():
    out = _run(["repro.launch.serve", "--arch", "tiny_dense", "--requests", "5",
                "--slots", "2", "--prompt-len", "12", "--max-new", "4",
                "--max-len", "32"])
    assert "served 5 requests" in out


@pytest.mark.slow
def test_ebft_run_cli_orderings():
    out = _run(["repro.launch.ebft_run", "--arch", "tiny_dense",
                "--pretrain-steps", "120", "--sparsity", "0.7",
                "--calib-samples", "16", "--epochs", "4",
                "--seq", "64"], timeout=900)
    # parse the printed perplexities: EBFT must improve on the pruned model
    ppls = {}
    for l in out.splitlines():
        parts = l.split()
        if len(parts) >= 3 and parts[1] == "ppl":
            ppls[parts[0]] = float(parts[2])
    assert "EBFT" in ppls and "wanda" in ppls, out
    assert ppls["EBFT"] < ppls["wanda"]


def test_paper_model_config_exists():
    """The paper's own evaluation model (Llama-7B) ships as a config."""
    from repro.configs import get_config

    cfg = get_config("llama_7b")
    assert cfg.num_layers == 32 and cfg.d_model == 4096 and cfg.d_ff == 11008
    from tests.test_arch_smoke import reduce_config
    from repro.models.model import build
    import jax

    m = build(reduce_config(cfg))
    params = m.init(jax.random.PRNGKey(0))
    assert m.num_blocks == 2

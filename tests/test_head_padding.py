"""Zero-padded head expansion must be EXACTLY the same function (the
distribution-layer claim behind launch/steps.padded_heads)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.distributed.head_padding import head_pad_mask, pad_attention_params
from repro.launch.steps import padded_heads
from repro.models.model import build


def _compare(cfg_old, cfg_new):
    m_old = build(cfg_old)
    m_new = build(cfg_new)
    params = m_old.init(jax.random.PRNGKey(0))
    padded = pad_attention_params(params, cfg_old, cfg_new)
    # shapes must match the padded model
    ref_shapes = jax.eval_shape(lambda: m_new.init(jax.random.PRNGKey(0)))
    for (pa, a), (pb, b) in zip(
        jax.tree_util.tree_flatten_with_path(padded)[0],
        jax.tree_util.tree_flatten_with_path(ref_shapes)[0],
    ):
        assert a.shape == b.shape, (pa, a.shape, b.shape)

    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg_old.vocab_size, (2, 24)), jnp.int32)
    out_old = m_old.forward(params, {"tokens": toks})
    out_new = m_new.forward(padded, {"tokens": toks})
    np.testing.assert_allclose(
        np.asarray(out_old), np.asarray(out_new), rtol=1e-5, atol=1e-5
    )
    return params, padded


def test_mha_padding_exact():
    base = get_config("tiny_dense")
    cfg_old = base.replace(num_heads=3, num_kv_heads=3, head_dim=16)
    cfg_new = cfg_old.replace(num_heads=4, num_kv_heads=4)
    _compare(cfg_old, cfg_new)


def test_gqa_padding_exact():
    base = get_config("tiny_dense")
    cfg_old = base.replace(num_heads=6, num_kv_heads=2, head_dim=16)
    cfg_new = cfg_old.replace(num_heads=8, num_kv_heads=2)  # group 3 -> 4
    _compare(cfg_old, cfg_new)


def test_head_pad_mask_freezes_pads():
    base = get_config("tiny_dense")
    cfg_old = base.replace(num_heads=6, num_kv_heads=2, head_dim=16)
    cfg_new = cfg_old.replace(num_heads=8, num_kv_heads=2)
    m_new = build(cfg_new)
    params = build(cfg_old).init(jax.random.PRNGKey(0))
    padded = pad_attention_params(params, cfg_old, cfg_new)
    mask = head_pad_mask(padded, cfg_old, cfg_new)

    # one masked SGD step keeps padded slots exactly zero
    rng = np.random.default_rng(1)
    toks = jnp.asarray(rng.integers(0, cfg_old.vocab_size, (2, 16)), jnp.int32)

    def loss(p):
        return m_new.loss(p, {"tokens": toks})[0]

    g = jax.grad(loss)(padded)
    g = jax.tree.map(lambda gr, mk: gr * mk.astype(gr.dtype), g, mask)
    stepped = jax.tree.map(lambda p, gr: p - 0.1 * gr, padded, g)

    wq = np.asarray(stepped["blocks"]["attn"]["wq"])  # (L, d, 8, hd)
    grouped = wq.reshape(wq.shape[0], wq.shape[1], 2, 4, wq.shape[-1])
    assert np.all(grouped[:, :, :, 3:, :] == 0.0), "padded q heads moved"
    wo = np.asarray(stepped["blocks"]["attn"]["wo"])  # (L, 8, hd, d)
    wog = wo.reshape(wo.shape[0], 2, 4, *wo.shape[2:])
    assert np.all(wog[:, :, 3:] == 0.0), "padded wo rows moved"

    # and WITHOUT the mask wo's pad rows WOULD move (their grad is the
    # uniform-softmax context x dy, which is nonzero — the mask is
    # load-bearing)
    unmasked = jax.tree.map(lambda p, gr: p - 0.1 * gr, padded, jax.grad(loss)(padded))
    wo2 = np.asarray(unmasked["blocks"]["attn"]["wo"])
    wog2 = wo2.reshape(wo.shape[0], 2, 4, *wo.shape[2:])
    assert not np.all(wog2[:, :, 3:] == 0.0)


def test_padded_heads_policy():
    """The launcher's padded-head table for the assigned archs on 16."""
    assert padded_heads(get_config("qwen1_5_4b"), 16) == (32, 32)      # MHA 20
    assert padded_heads(get_config("qwen2_5_32b"), 16) == (48, 8)      # GQA 40/8
    assert padded_heads(get_config("qwen1_5_110b"), 16) == (64, 8)     # already ok
    assert padded_heads(get_config("nemotron_4_15b"), 16) == (48, 8)
    assert padded_heads(get_config("llava_next_mistral_7b"), 16) == (32, 8)

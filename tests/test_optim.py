"""Optimizer / schedule / gradient-compression unit tests."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.optim import grad_compress as GC
from repro.optim.optimizers import (
    adam, adamw, apply_updates, clip_by_global_norm, sgd,
)
from repro.optim.schedules import (
    constant, linear_decay, plateau_early_stop, warmup_cosine,
)

SET = settings(max_examples=20, deadline=None, derandomize=True)


def test_adam_first_step_matches_analytic():
    """After one step from zero moments, Adam's update is -lr * sign-ish:
    m_hat = g, v_hat = g^2 -> update = -lr * g / (|g| + eps)."""
    lr = 1e-2
    opt = adam(lr)
    params = {"w": jnp.asarray([1.0, -2.0, 3.0])}
    g = {"w": jnp.asarray([0.5, -0.25, 2.0])}
    state = opt.init(params)
    upd, state = opt.update(g, state, params)
    expect = -lr * np.sign([0.5, -0.25, 2.0])
    np.testing.assert_allclose(np.asarray(upd["w"]), expect, rtol=1e-4)


def test_adamw_decouples_weight_decay():
    lr, wd = 1e-2, 0.1
    opt = adamw(lr, weight_decay=wd)
    params = {"w": jnp.asarray([2.0])}
    g = {"w": jnp.asarray([0.0])}
    state = opt.init(params)
    upd, _ = opt.update(g, state, params)
    # zero grad -> update is pure decay: -lr * wd * w
    np.testing.assert_allclose(np.asarray(upd["w"]), [-lr * wd * 2.0], rtol=1e-5)


def test_sgd_momentum_accumulates():
    opt = sgd(1.0, momentum=0.5)
    params = {"w": jnp.zeros(1)}
    state = opt.init(params)
    g = {"w": jnp.asarray([1.0])}
    u1, state = opt.update(g, state, params)
    u2, state = opt.update(g, state, params)
    assert float(u2["w"][0]) < float(u1["w"][0]) < 0  # |u2| = 1.5 > |u1| = 1


def test_apply_updates_adds():
    p = {"w": jnp.asarray([1.0])}
    u = {"w": jnp.asarray([-0.25])}
    np.testing.assert_allclose(np.asarray(apply_updates(p, u)["w"]), [0.75])


@SET
@given(st.integers(0, 2**31 - 1), st.floats(0.1, 10.0))
def test_clip_by_global_norm(seed, clip):
    rng = np.random.default_rng(seed)
    g = {"a": jnp.asarray(rng.normal(size=(7,)).astype(np.float32)),
         "b": jnp.asarray(rng.normal(size=(3, 2)).astype(np.float32))}
    clipped, norm = clip_by_global_norm(g, clip)
    total = np.sqrt(sum(float(jnp.sum(x ** 2)) for x in jax.tree.leaves(clipped)))
    assert total <= clip * 1.001
    if float(norm) <= clip:  # no-op when under the threshold
        for a, b in zip(jax.tree.leaves(g), jax.tree.leaves(clipped)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)


def test_warmup_cosine_shape():
    f = warmup_cosine(1.0, warmup=10, total=100)
    assert float(f(0)) == 0.0
    assert abs(float(f(10)) - 1.0) < 1e-6
    assert float(f(5)) == pytest.approx(0.5, rel=1e-5)
    assert float(f(100)) < 1e-3
    # monotone decay after warmup
    vals = [float(f(s)) for s in range(10, 101, 10)]
    assert all(a >= b for a, b in zip(vals, vals[1:]))


def test_linear_decay_endpoints():
    f = linear_decay(2.0, warmup=0, total=10, floor=0.5)
    assert float(f(0)) == pytest.approx(2.0)
    assert float(f(10)) == pytest.approx(0.5)


def test_plateau_early_stop():
    assert not plateau_early_stop([1.0, 0.5], patience=2)
    # recent best (0.4998) improves on prior best (0.5) by <0.1% -> plateau
    assert plateau_early_stop([1.0, 0.5, 0.4999, 0.4998], patience=2, rel_tol=1e-3)
    assert not plateau_early_stop([1.0, 0.5, 0.4, 0.3], patience=2, rel_tol=1e-3)


# ---------------------------------------------------------------------------
@SET
@given(st.integers(0, 2**31 - 1), st.sampled_from([0.05, 0.1, 0.5]))
def test_compression_error_feedback_conserves_signal(seed, ratio):
    """sent + residual == grad + old residual (nothing is lost)."""
    rng = np.random.default_rng(seed)
    g = {"w": jnp.asarray(rng.normal(size=(64, 64)).astype(np.float32))}
    err = {"w": jnp.asarray(rng.normal(size=(64, 64)).astype(np.float32) * 0.1)}
    sent, new_err = GC.compress(g, err, ratio)
    lhs = np.asarray(sent["w"], np.float32) + np.asarray(new_err["w"])
    rhs = np.asarray(g["w"], np.float32) + np.asarray(err["w"])
    np.testing.assert_allclose(lhs, rhs, rtol=1e-5, atol=1e-5)
    # sparsity of the sent tensor ~ ratio
    nz = float((np.asarray(sent["w"]) != 0).mean())
    assert nz <= ratio * 1.5 + 1e-3


def test_compression_skips_tiny_leaves():
    g = {"w": jnp.ones((4,))}
    err = {"w": jnp.zeros((4,))}
    sent, new_err = GC.compress(g, err, 0.01)
    np.testing.assert_array_equal(np.asarray(sent["w"]), np.ones(4))


def test_compressed_bytes_estimate():
    params = {"w": jnp.zeros((1024, 64))}
    full = GC.compressed_bytes(params, 1.0)
    tenth = GC.compressed_bytes(params, 0.1)
    assert tenth < full

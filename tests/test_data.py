"""Synthetic data pipeline: determinism, statistical structure, tasks."""
from __future__ import annotations

import numpy as np
import pytest

from repro.data.tokens import (
    CorpusConfig, SyntheticCorpus, calibration_set, cloze_task,
    corpus_iterator, eval_set,
)


@pytest.fixture(scope="module")
def corpus():
    return SyntheticCorpus(CorpusConfig(vocab_size=512))


def test_calibration_deterministic(corpus):
    a = calibration_set(corpus, 8, 64)
    b = calibration_set(corpus, 8, 64)
    np.testing.assert_array_equal(a, b)
    assert a.shape == (8, 64) and a.dtype == np.int32
    assert a.min() >= 0 and a.max() < 512


def test_eval_disjoint_seed_from_calib(corpus):
    a = calibration_set(corpus, 8, 64)
    b = eval_set(corpus, 8, 64)
    assert not np.array_equal(a, b)


def test_zipf_unigram_structure(corpus):
    """Token frequencies must be heavy-headed (Zipf-ish): the most common
    token far exceeds the mean frequency."""
    it = corpus_iterator(corpus, batch=16, seq_len=256, seed=0)
    toks = next(it).reshape(-1)
    counts = np.bincount(toks, minlength=512).astype(float)
    assert counts.max() > 10 * counts.mean()


def test_markov_structure_carries_information(corpus):
    """Bigram conditional entropy must be lower than unigram entropy —
    otherwise LM training on this corpus is meaningless."""
    it = corpus_iterator(corpus, batch=32, seq_len=512, seed=1)
    toks = next(it)
    flat = toks.reshape(-1)
    V = 512
    uni = np.bincount(flat, minlength=V) + 1e-9
    p_uni = uni / uni.sum()
    H_uni = -(p_uni * np.log(p_uni)).sum()

    # conditional entropy via most frequent predecessor classes
    pairs = np.stack([toks[:, :-1].reshape(-1), toks[:, 1:].reshape(-1)])
    top_prev = np.argsort(-uni)[:20]
    H_cond = []
    for t in top_prev:
        nxt = pairs[1][pairs[0] == t]
        if len(nxt) < 50:
            continue
        c = np.bincount(nxt, minlength=V) + 1e-9
        p = c / c.sum()
        H_cond.append(-(p * np.log(p)).sum())
    assert np.mean(H_cond) < H_uni - 0.1


def test_cloze_task_well_formed(corpus):
    ctx, true_next, distract = cloze_task(corpus, 32, 64)
    assert ctx.shape == (32, 64)
    assert (true_next != distract).all()


def test_corpus_iterator_reproducible(corpus):
    a = next(corpus_iterator(corpus, 4, 32, seed=7))
    b = next(corpus_iterator(corpus, 4, 32, seed=7))
    np.testing.assert_array_equal(a, b)

"""repro.obs: null-mode invariants, span nesting/timing, metric
instruments, sink round-trips through the report CLI, schema
validation, profiling hooks, and the instrumented-pipeline integration
test (tiny ebft_run -> valid BENCH_ebft.json)."""
from __future__ import annotations

import json

import jax
import jax.numpy as jnp
import pytest

from repro.obs import metrics as OM
from repro.obs import trace as OT
from repro.obs.__main__ import main as obs_cli
from repro.obs.profile import ebft_live_block_bytes, is_abstract, profiled
from repro.obs.run import current_run, start_run, validate_payload
from repro.obs.sinks import load_artifact, read_jsonl


@pytest.fixture(autouse=True)
def _obs_reset():
    """Every test starts and ends with the null singletons installed."""
    OT.set_tracer(None)
    OM.set_registry(None)
    yield
    run = current_run()
    if run is not None:
        run.finish()
    OT.set_tracer(None)
    OM.set_registry(None)


# ---------------------------------------------------------------------------
# null mode: disabled observability produces zero events
# ---------------------------------------------------------------------------
def test_null_mode_no_events_no_state():
    assert not OT.enabled() and not OM.enabled()
    with OT.span("outer", a=1) as sp:
        with OT.span("inner") as inner:
            assert inner is sp is OT.NULL_SPAN  # one shared instance
        sp.set(b=2)
        assert sp.fence(42) == 42  # fence is identity when off
    OM.counter("c").inc(5)
    OM.gauge("g").set(3.0)
    OM.histogram("h").observe(1.0)
    OM.series("s").append(1.0, step=0)
    assert OT.get_tracer().tree() == []
    assert OM.summary() == {}
    assert sp.duration == 0.0 and sp.attrs == {}


# ---------------------------------------------------------------------------
# spans: nesting + timing monotonicity
# ---------------------------------------------------------------------------
def test_span_nesting_and_timing_monotonicity():
    run = start_run("t", console=False)
    with OT.span("walk", epochs=2) as w:
        with OT.span("block", index=0):
            pass
        with OT.span("block", index=1) as b1:
            with OT.span("step"):
                pass
            b1.set(loss=0.5)

    forest = run.tracer.tree()
    assert [r["name"] for r in forest] == ["walk"]
    blocks = forest[0]["children"]
    assert [b["name"] for b in blocks] == ["block", "block"]
    assert blocks[1]["children"][0]["name"] == "step"
    assert blocks[1]["attrs"] == {"index": 1, "loss": 0.5}

    # monotonicity: children start no earlier than the parent, end no
    # later, and sibling starts are ordered
    assert w.duration >= b1.duration >= b1.children[0].duration >= 0.0
    assert blocks[0]["start"] >= forest[0]["start"]
    assert blocks[1]["start"] >= blocks[0]["start"] + blocks[0]["duration_s"]
    for node in (forest[0], blocks[0], blocks[1]):
        assert node["duration_s"] >= sum(
            c["duration_s"] for c in node.get("children", [])
        )

    run.finish()
    assert not OT.enabled()  # finish restores the null singletons


def test_span_stack_unwinds_on_exception():
    run = start_run("t", console=False)
    with pytest.raises(RuntimeError):
        with OT.span("outer"):
            with OT.span("inner"):
                raise RuntimeError("boom")
    # both spans closed despite the exception; a new span is a root
    with OT.span("after"):
        pass
    assert [r["name"] for r in run.tracer.tree()] == ["outer", "after"]


# ---------------------------------------------------------------------------
# metrics instruments
# ---------------------------------------------------------------------------
def test_metric_instruments_and_summaries():
    start_run("t", console=False)
    OM.counter("tokens").inc(3)
    OM.counter("tokens").inc(2)
    g = OM.gauge("live_bytes")
    for v in (10.0, 50.0, 20.0):
        g.set(v)
    h = OM.histogram("lat")
    for v in (1.0, 2.0, 3.0, 4.0):
        h.observe(v)
    s = OM.series("loss")
    s.append(2.0, step=0)
    s.append(1.0, step=10)

    out = OM.summary()
    assert out["tokens"] == {"kind": "counter", "value": 5.0}
    assert out["live_bytes"]["last"] == 20.0
    assert out["live_bytes"]["max"] == 50.0  # peak survives the summary
    assert out["live_bytes"]["min"] == 10.0
    assert out["lat"]["count"] == 4 and out["lat"]["mean"] == 2.5
    assert out["lat"]["min"] == 1.0 and out["lat"]["max"] == 4.0
    assert out["loss"]["first"] == 2.0 and out["loss"]["last"] == 1.0
    assert out["loss"]["points"] == [[0.0, 2.0], [10.0, 1.0]]

    with pytest.raises(TypeError):  # kind mismatch is a bug, not a merge
        OM.gauge("tokens")


# ---------------------------------------------------------------------------
# sinks: JSONL round-trip through the report CLI
# ---------------------------------------------------------------------------
def test_jsonl_roundtrip_and_report_cli(tmp_path, capsys):
    jsonl = tmp_path / "events.jsonl"
    summary = tmp_path / "BENCH_t.json"
    run = start_run("roundtrip", config="tiny_dense", method="wanda",
                    sparsity=0.5, console=False, jsonl_path=str(jsonl))
    with OT.span("phase/work", what="stuff"):
        OM.counter("work/items").inc(7)
    run.finish(extra={"answer": 42}, summary_path=str(summary))

    events = read_jsonl(str(jsonl))
    assert events[0]["type"] == "manifest"
    assert events[0]["manifest"]["name"] == "roundtrip"
    kinds = {e["type"] for e in events[1:]}
    assert {"counter", "span"} <= kinds
    span_ev = next(e for e in events if e["type"] == "span")
    assert span_ev["name"] == "phase/work" and span_ev["duration_s"] >= 0

    # the report CLI renders both artifact formats
    for artifact in (str(summary), str(jsonl)):
        assert obs_cli(["report", artifact]) == 0
        out = capsys.readouterr().out
        assert "roundtrip" in out and "phase/work" in out
    assert obs_cli(["report", str(summary), "--json"]) == 0
    payload = json.loads(capsys.readouterr().out)
    assert payload["answer"] == 42
    assert payload["metrics"]["work/items"]["value"] == 7.0

    # validate: summary passes (with required keys), raw JSONL is not a
    # summary artifact and must fail
    assert obs_cli(["validate", str(summary), "--require", "answer"]) == 0
    capsys.readouterr()
    assert obs_cli(["validate", str(jsonl)]) == 1
    capsys.readouterr()
    assert obs_cli(["report", str(tmp_path / "missing.json")]) == 2


def test_validate_payload_rejects_malformed():
    run = start_run("ok", console=False)
    payload = run.finish()
    assert validate_payload(payload) == []
    assert validate_payload(payload, require=["blocks"]) \
        == ["missing required key 'blocks'"]

    bad = dict(payload, manifest=dict(payload["manifest"], schema="nope/v9"))
    assert any("schema" in p for p in validate_payload(bad))
    assert validate_payload({"metrics": {}, "trace": []}) \
        == ["missing 'manifest' object"]
    assert validate_payload([1, 2]) \
        == ["artifact is list, expected object"]


def test_validate_payload_dispatch_budget():
    run = start_run("ok", console=False)
    payload = run.finish()
    # no dispatch object at all
    assert any("dispatch" in p for p in
               validate_payload(payload, max_dispatches_per_block=4))
    within = dict(payload, dispatch={"per_block_max": 3})
    assert validate_payload(within, max_dispatches_per_block=4) == []
    over = dict(payload, dispatch={"per_block_max": 9})
    probs = validate_payload(over, max_dispatches_per_block=4)
    assert probs and "exceeds budget 4" in probs[0]
    # malformed field type
    bad = dict(payload, dispatch={"per_block_max": "lots"})
    assert any("non-integer" in p for p in
               validate_payload(bad, max_dispatches_per_block=4))
    # no budget requested -> no dispatch requirements
    assert validate_payload(payload) == []


# ---------------------------------------------------------------------------
# profiling
# ---------------------------------------------------------------------------
def test_profiled_fn_splits_compile_from_execution():
    start_run("t", console=False)
    f = profiled(jax.jit(lambda x: x * 2.0 + 1.0), "test/step")
    x = jnp.arange(8.0)
    for _ in range(3):
        out = f(x)
    assert out[1] == 3.0
    s = OM.summary()
    assert s["test/step/compiles"]["value"] == 1.0  # one signature
    assert s["test/step/exec_s"]["count"] == 3
    assert s["test/step/lower_s"]["last"] >= 0.0
    assert s["test/step/compile_s"]["last"] > 0.0
    # a second shape triggers exactly one more compile
    f(jnp.arange(4.0))
    assert OM.summary()["test/step/compiles"]["value"] == 2.0


def test_profiled_fn_passthrough_when_disabled_or_traced():
    f = profiled(jax.jit(lambda x: x + 1.0), "test/off")
    assert float(f(jnp.float32(1.0))) == 2.0  # obs off: raw call
    start_run("t", console=False)
    # under an outer trace the wrapper must not lower/fence tracers
    outer = jax.jit(lambda x: f(x) * 2.0)
    assert float(outer(jnp.float32(1.0))) == 4.0
    s = OM.summary()
    assert "test/off/exec_s" not in s and "test/off/compiles" not in s


def test_first_call_timer_books_compile_once_per_signature():
    from repro.obs.profile import FirstCallTimer, compile_clock

    start_run("t", console=False)
    clock = compile_clock()
    clock.take()  # drain anything earlier tests left pending
    timed = FirstCallTimer(jax.jit(lambda x, i: x + i, static_argnames="i"))
    x = jnp.arange(4.0)

    timed(x, i=0)
    assert clock.take() > 0.0          # first call: trace+compile booked
    timed(x, i=0)
    assert clock.take() == 0.0         # warm call books nothing
    # a different static value is a different jit cache entry, so the
    # signature must treat non-array leaves by value
    timed(x, i=1)
    assert clock.take() > 0.0
    # clock drains: a second take with nothing new is zero
    assert clock.take() == 0.0


def test_first_call_timer_passthrough_when_disabled():
    from repro.obs.profile import FirstCallTimer, compile_clock

    clock = compile_clock()
    clock.take()
    timed = FirstCallTimer(jax.jit(lambda x: x * 2.0))
    assert float(timed(jnp.float32(3.0))) == 6.0  # obs off: raw call
    assert clock.take() == 0.0


def test_is_abstract_and_live_bytes():
    assert not is_abstract(jnp.ones(3), {"a": 1.0})
    seen = []
    jax.jit(lambda x: seen.append(is_abstract(x)) or x)(jnp.ones(2))
    assert seen == [True]
    block = {"w": jnp.ones((4, 4), jnp.float32)}
    masks = {"w": jnp.ones((4, 4), jnp.float32)}
    # 16 weights f32 + 16 mask f32 + 2 moments * 16 * 4B
    assert ebft_live_block_bytes(block, masks) == 64 + 64 + 128


# ---------------------------------------------------------------------------
# integration: the instrumented pipeline emits a valid BENCH_ebft.json
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_ebft_run_emits_valid_bench_artifact(tmp_path, capsys):
    from repro.launch.ebft_run import main as ebft_main

    bench = tmp_path / "BENCH_ebft.json"
    jsonl = tmp_path / "events.jsonl"
    ebft_main(["--arch", "tiny_dense", "--pretrain-steps", "30",
               "--batch", "8", "--seq", "32", "--calib-samples", "8",
               "--epochs", "2", "--bench-out", str(bench),
               "--obs-jsonl", str(jsonl)])
    console = capsys.readouterr().out
    assert "EBFT ppl" in console  # console sink preserved

    payload = load_artifact(str(bench))
    assert validate_payload(
        payload,
        require=["blocks", "phases", "perplexity", "ebft", "dispatch",
                 "walk_phases"],
        max_dispatches_per_block=4,  # epochs (2) + 2, the CI budget
    ) == []
    assert payload["manifest"]["config"] == "tiny_dense"
    assert payload["manifest"]["method"] == "wanda"

    # per-block reconstruction data survived the launcher (the BlockReport
    # plumbing bug this layer fixed)
    blocks = payload["blocks"]
    assert blocks and len(blocks) == payload["ebft"]["num_blocks"]
    for b in blocks:
        assert b["epochs_run"] >= 1
        assert b["loss_after"] <= b["loss_before"]
        assert b["early_stop"] in ("plateau", "max_epochs")
        # history = [E_before] + one entry per epoch run
        assert len(b["history"]) == b["epochs_run"] + 1
        assert b["live_bytes"] > 0
        assert b["path"] == "fused"
        assert b["dispatches"] == 1 and b["host_syncs"] == 1

    # the fused-walk accounting: per-block = 1 tune + 2 stream advances
    assert payload["ebft"]["fused_epochs"] is True
    assert payload["dispatch"]["per_block_max"] == 3
    assert payload["dispatch"]["fused_all_blocks"] is True
    # per-phase walk wall-clock was recorded, with first-call
    # (trace+compile) time split out of the steady-state sums
    for phase in ("teacher", "tune", "student"):
        assert payload["walk_phases"][phase] > 0
        assert payload["walk_phases"][f"{phase}_compile"] >= 0
    # the walk definitely compiled something (adv_scan per block index,
    # the fused tune step) and none of it may hide in the phase sums
    assert sum(payload["walk_phases"][f"{p}_compile"]
               for p in ("teacher", "tune", "student")) > 0

    # the tile-plan autotuner section is present (default mode: cache)
    kt = payload["kernel_tuning"]
    assert kt["mode"] == "cache"
    assert kt["searches"] == 0 and kt["search_s"] == 0.0
    assert kt["hits"] + kt["misses"] >= 1  # pretune resolved the workloads

    # phases + the paper's streaming-memory measurement
    assert {"pretrain", "prune", "ebft", "eval_dense"} <= set(payload["phases"])
    assert all(v >= 0 for v in payload["phases"].values())
    peak = payload["ebft"]["peak_live_block_bytes"]
    assert peak == max(b["live_bytes"] for b in blocks)
    assert payload["metrics"]["ebft/live_block_bytes"]["max"] == peak
    assert {"dense", "wanda", "EBFT"} <= set(payload["perplexity"])

    # trace forest contains the phase spans with nested ebft blocks
    names = {s["name"] for s in payload["trace"]}
    assert {"phase/pretrain", "phase/prune", "phase/ebft"} <= names
    ebft_phase = next(s for s in payload["trace"] if s["name"] == "phase/ebft")
    walk = ebft_phase["children"][0]
    assert walk["name"] == "ebft/walk"
    # the stacked walk wraps each visit in teacher/tune/student phase
    # spans; ebft/block nests inside walk/tune
    walk_names = [c["name"] for c in walk["children"]]
    assert {"walk/teacher", "walk/tune", "walk/student"} <= set(walk_names)
    tune_spans = [c for c in walk["children"] if c["name"] == "walk/tune"]
    assert len([g for t in tune_spans for g in t.get("children", [])
                if g["name"] == "ebft/block"]) == len(blocks)

    # event stream is crash-safe JSONL with the same manifest
    events = read_jsonl(str(jsonl))
    assert events[0]["manifest"]["name"] == "ebft_run"
    assert any(e.get("name") == "ebft/block" for e in events)

    # report CLI renders the artifact
    assert obs_cli(["report", str(bench)]) == 0
    out = capsys.readouterr().out
    assert "ebft/block" in out or "blocks" in out

    # run state was released
    assert current_run() is None and not OT.enabled()

"""RunSpec facade: canonical parsing, the deprecation shim, per-kind
defaults, and the argv -> spec -> manifest -> spec round-trip."""
from __future__ import annotations

import warnings

import pytest

from repro.launch.api import (
    KINDS,
    RunSpec,
    _reset_deprecation_warnings,
    build_parser,
)


def test_canonical_parse_ebft():
    spec = RunSpec.from_argv("ebft", [
        "--arch", "tiny_dense", "--lr", "0.5", "--epochs", "3",
        "--mesh-data", "4", "--mesh-model", "2",
    ])
    assert spec.kind == "ebft"
    assert spec.lr == 0.5 and spec.epochs == 3
    assert (spec.mesh_data, spec.mesh_model) == (4, 2)
    assert spec.bench_out == "BENCH_ebft.json"  # per-kind default


def test_per_kind_defaults_diverge():
    t = RunSpec.from_argv("train", [])
    e = RunSpec.from_argv("ebft", [])
    assert t.batch == 16 and e.batch == 32
    assert t.lr == 3e-3 and e.lr == 1e-2
    # train auto-sizes its mesh from the host (0 = auto, pre-RunSpec
    # behavior); ebft must stay bit-for-bit single-device by default
    assert t.mesh_data == 0 and e.mesh_data == 1


def test_every_kind_builds_a_parser():
    for kind in KINDS:
        ap = build_parser(kind)
        assert ap.format_help()  # renders without raising


def test_unknown_kind_rejected():
    with pytest.raises(ValueError, match="unknown launcher kind"):
        RunSpec.from_argv("bogus", [])


def test_deprecated_flag_warns_once_and_stores_canonically():
    _reset_deprecation_warnings()
    with pytest.warns(DeprecationWarning, match="ebft-lr"):  # api: deprecated-ok
        spec = RunSpec.from_argv("ebft", ["--ebft-lr", "0.25"])  # api: deprecated-ok
    assert spec.lr == 0.25
    # second use in the same process: silent (warn-once)
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        spec2 = RunSpec.from_argv("ebft", ["--ebft-lr", "0.125"])  # api: deprecated-ok
    assert spec2.lr == 0.125


def test_serve_batch_means_slots_through_the_shim():
    _reset_deprecation_warnings()
    with pytest.warns(DeprecationWarning, match="--slots"):
        spec = RunSpec.from_argv("serve", ["--batch", "2"])  # api: deprecated-ok
    assert spec.slots == 2


def test_train_mesh_axis_shims():
    _reset_deprecation_warnings()
    with pytest.warns(DeprecationWarning):
        spec = RunSpec.from_argv(
            "train", ["--data", "4", "--model-axis", "2"])  # api: deprecated-ok
    assert (spec.mesh_data, spec.mesh_model) == (4, 2)


def test_canonical_flag_never_warns():
    _reset_deprecation_warnings()
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        RunSpec.from_argv("ebft", ["--lr", "0.5", "--epochs", "2"])


def test_manifest_round_trip():
    spec = RunSpec.from_argv("ebft", [
        "--arch", "tiny_moe", "--epochs", "2", "--seq", "64",
        "--mesh-data", "4", "--mesh-model", "2", "--method", "magnitude",
    ])
    man = spec.to_manifest()
    # flat legacy keys stay readable for existing artifact consumers
    assert man["ebft_epochs"] == 2
    assert man["mesh"] == {"data": 4, "model": 2}
    # and the run_spec section round-trips exactly
    assert RunSpec.from_manifest(man) == spec


def test_from_manifest_requires_run_spec_section():
    with pytest.raises(ValueError, match="run_spec"):
        RunSpec.from_manifest({"config": "tiny_dense"})


def test_no_obs_short_circuits_start_obs_run():
    spec = RunSpec.from_argv("ebft", ["--no-obs"])
    assert spec.start_obs_run() is None


# ---------------------------------------------------------------------------
# parse-time validation (validate() via from_argv)
# ---------------------------------------------------------------------------
def test_prefetch_depth_below_one_rejected_at_parse_time(capsys):
    with pytest.raises(SystemExit):
        RunSpec.from_argv("ebft", ["--prefetch-depth", "0"])
    assert "prefetch-depth" in capsys.readouterr().err
    # and as a direct ValueError from validate() for programmatic callers
    with pytest.raises(ValueError, match="prefetch-depth.*>= 1"):
        RunSpec(kind="ebft", prefetch_depth=0).validate()


def test_kernel_tune_flag_choices():
    for mode in ("off", "cache", "search"):
        assert RunSpec.from_argv(
            "ebft", ["--kernel-tune", mode]).kernel_tune == mode
    with pytest.raises(SystemExit):  # argparse choices reject it
        RunSpec.from_argv("ebft", ["--kernel-tune", "always"])
    with pytest.raises(ValueError, match="kernel-tune"):
        RunSpec(kind="ebft", kernel_tune="always").validate()


def test_kernel_tune_modes_literal_matches_tuning_module():
    # api.py keeps the literal so parsing never imports the kernels
    # package; this is the pin that keeps the two in sync
    from repro.kernels import tuning
    from repro.launch.api import KERNEL_TUNE_MODES

    assert KERNEL_TUNE_MODES == tuning.MODES


def test_from_manifest_skips_validation():
    # old artifacts may predate the prefetch_depth >= 1 launcher rule;
    # round-tripping them must not raise
    man = RunSpec.from_argv("ebft", []).to_manifest()
    man["run_spec"]["prefetch_depth"] = 0
    assert RunSpec.from_manifest(man).prefetch_depth == 0

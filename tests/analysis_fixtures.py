"""Seeded-violation configs for the static analyzer's own tests.

Loaded by ``python -m repro.analysis --extra-config-module analysis_fixtures``
(and by tests/test_analysis.py directly). Each config plants one specific
error-severity violation the analyzer must catch:

* ``bad_tiles`` — d_ff=999 (odd, >128): no power-of-two tile divides the
  MLP matmul's reduction/output dims -> KER001;
* ``bad_heads`` — num_heads=5 with num_kv_heads=2: GQA grouping broken
  -> CFG002.

Kept tiny so they double as their own smoke variants for the trace passes.
"""
from __future__ import annotations

from repro.configs.base import ModelConfig

BAD_TILES = ModelConfig(
    name="bad_tiles", family="dense", num_layers=2, d_model=64,
    num_heads=4, num_kv_heads=4, d_ff=999, vocab_size=256,
)

BAD_HEADS = ModelConfig(
    name="bad_heads", family="dense", num_layers=2, d_model=64,
    num_heads=5, num_kv_heads=2, d_ff=128, vocab_size=256, head_dim=16,
)

ANALYSIS_CONFIGS = [("bad_tiles", BAD_TILES), ("bad_heads", BAD_HEADS)]

"""Model-family behaviour: forward/loss, the block API EBFT consumes, and
the serving path (prefill + decode == full forward)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.models.model import build
from tests.conftest import TINY_ARCHS, make_batch

SHAPE = ShapeConfig("t", 64, 2, "train")


@pytest.mark.parametrize("arch", TINY_ARCHS)
def test_forward_loss_shapes_and_finite(arch):
    cfg = get_config(arch)
    m = build(cfg)
    params = m.init(jax.random.PRNGKey(0))
    batch = make_batch(m, SHAPE, np.random.default_rng(0))
    loss, metrics = m.loss(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{arch} loss not finite"
    logits = m.forward(params, batch)
    assert logits.shape[-1] == cfg.padded_vocab
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("arch", TINY_ARCHS)
def test_block_api_roundtrip(arch):
    """get_block/set_block are inverses; set_block(other) changes output."""
    cfg = get_config(arch)
    m = build(cfg)
    params = m.init(jax.random.PRNGKey(0))
    bp0 = m.get_block(params, 0)
    params2 = m.set_block(params, 0, jax.tree.map(lambda a: a * 0.5, bp0))
    bp1 = m.get_block(params2, 0)
    for a, b in zip(jax.tree.leaves(bp0), jax.tree.leaves(bp1)):
        np.testing.assert_allclose(
            np.asarray(a, np.float32) * 0.5, np.asarray(b, np.float32), rtol=1e-6
        )
    # other blocks untouched
    if m.num_blocks > 1:
        a0 = jax.tree.leaves(m.get_block(params, 1))
        a1 = jax.tree.leaves(m.get_block(params2, 1))
        for x, y in zip(a0, a1):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


@pytest.mark.parametrize("arch", ["tiny_dense", "tiny_ssm", "tiny_moe"])
def test_blockwise_apply_equals_forward(arch):
    """embed -> apply_block (x L) -> finalize must reproduce forward():
    the invariant EBFT's streaming walk relies on."""
    cfg = get_config(arch)
    m = build(cfg)
    params = m.init(jax.random.PRNGKey(1))
    batch = make_batch(m, SHAPE, np.random.default_rng(1))
    h, pos = m.embed_tokens(params, batch)
    for i in range(m.num_blocks):
        h = m.apply_block(params, i, m.get_block(params, i), h, pos)
    logits_blockwise = m.finalize(params, h)
    logits_forward = m.forward(params, batch)
    np.testing.assert_allclose(
        np.asarray(logits_blockwise), np.asarray(logits_forward),
        rtol=2e-4, atol=2e-4,
    )


def test_hybrid_blockwise_walk_covers_shared_block(tiny_corpus=None):
    """Zamba2 walk: mamba blocks via execution plan + shared attn block."""
    cfg = get_config("tiny_hybrid")
    m = build(cfg)
    params = m.init(jax.random.PRNGKey(1))
    # shared block index = num_blocks - 1 by convention
    shared = m.get_block(params, m.num_blocks - 1)
    assert "attn" in shared


@pytest.mark.parametrize("arch", ["tiny_dense", "tiny_moe", "tiny_ssm", "tiny_hybrid"])
def test_prefill_decode_matches_forward(arch):
    """Greedy next-token from (prefill + decode_step) must equal the
    argmax from the full forward pass at the same positions.

    MoE note: capacity-based dispatch drops depend on the *total* token
    count, which differs between forward (S) and prefill (S-1) — so the
    invariant is exact only when capacity is large enough for zero drops
    (cf >= E/k). That IS the invariant: routing itself is causal."""
    cfg = get_config(arch)
    if cfg.moe_num_experts:
        cfg = cfg.replace(
            moe_capacity_factor=float(cfg.moe_num_experts) / cfg.moe_top_k + 1.0
        )
    m = build(cfg)
    params = m.init(jax.random.PRNGKey(2))
    B, S = 2, 32
    rng = np.random.default_rng(2)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, size=(B, S)), jnp.int32)

    full = m.forward(params, {"tokens": toks})  # (B, S, V)

    state = m.init_serve_state(B, S + 4)
    logits_p, state = m.prefill(params, {"tokens": toks[:, :-1]}, state)
    # prefill returns last-position logits == full[:, S-2]
    np.testing.assert_allclose(
        np.asarray(logits_p[:, -1]), np.asarray(full[:, S - 2]),
        rtol=2e-3, atol=2e-3,
    )
    # decode one token (the actual last token) -> must match full[:, S-1]
    logits_d, state = m.decode_step(params, toks[:, -1:], state)
    np.testing.assert_allclose(
        np.asarray(logits_d[:, -1]), np.asarray(full[:, S - 1]),
        rtol=2e-3, atol=2e-3,
    )


def test_encdec_prefill_decode_consistent():
    cfg = get_config("tiny_encdec")
    m = build(cfg)
    params = m.init(jax.random.PRNGKey(3))
    B, S = 2, 32
    rng = np.random.default_rng(3)
    batch = make_batch(m, ShapeConfig("t", S, B, "train"), rng)
    full = m.forward(params, batch)
    state = m.init_serve_state(B, S)
    logits_p, state = m.prefill(
        params, {"tokens": batch["tokens"][:, :-1], "frames": batch["frames"]}, state
    )
    logits_d, _ = m.decode_step(params, batch["tokens"][:, -1:], state)
    np.testing.assert_allclose(
        np.asarray(logits_d[:, -1]), np.asarray(full[:, S - 1]), rtol=2e-3, atol=2e-3
    )


def test_vlm_concatenates_patches_before_tokens():
    cfg = get_config("tiny_vlm")
    m = build(cfg)
    params = m.init(jax.random.PRNGKey(4))
    batch = make_batch(m, SHAPE, np.random.default_rng(4))
    h, pos = m.embed_tokens(params, batch)
    P = batch["patches"].shape[1]
    assert h.shape[1] == P + batch["tokens"].shape[1]


def test_param_count_matches_actual_leaves():
    """ModelConfig.param_count (used for MODEL_FLOPS) must track the real
    parameter total within the vocab-padding tolerance."""
    for arch in ("tiny_dense", "tiny_moe", "tiny_ssm"):
        cfg = get_config(arch)
        m = build(cfg)
        params = m.init(jax.random.PRNGKey(0))
        actual = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
        predicted = cfg.param_count()
        assert abs(actual - predicted) / actual < 0.02, (
            f"{arch}: predicted {predicted} vs actual {actual}"
        )


def test_moe_aux_loss_present_and_finite():
    cfg = get_config("tiny_moe")
    m = build(cfg)
    params = m.init(jax.random.PRNGKey(0))
    batch = make_batch(m, SHAPE, np.random.default_rng(0))
    loss, metrics = m.loss(params, batch)
    assert "aux" in metrics and bool(jnp.isfinite(metrics["aux"]))


@pytest.mark.parametrize("impl", ["dot", "chunked"])
def test_attention_impls_agree(impl):
    """chunked (flash-equivalent) attention must match dot attention."""
    from repro.models.layers import attend
    rng = np.random.default_rng(5)
    q = jnp.asarray(rng.normal(size=(2, 96, 4, 32)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(2, 96, 2, 32)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(2, 96, 2, 32)).astype(np.float32))
    ref = attend(q, k, v, causal=True, impl="dot")
    out = attend(q, k, v, causal=True, impl=impl, chunk=32, q_chunk=32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-4, atol=1e-4)

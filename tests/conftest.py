"""Shared fixtures. NOTE: no XLA_FLAGS here — tests run on the 1 real CPU
device; only the dry-run (launch/dryrun.py) forces 512 placeholder devices,
and the distribution tests that need >1 device spawn subprocesses."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data.tokens import (
    CorpusConfig, SyntheticCorpus, calibration_set, corpus_iterator, eval_set,
)
from repro.models.model import build
from repro.optim.optimizers import adamw
from repro.training.train_loop import make_train_step

TINY_ARCHS = [
    "tiny_dense", "tiny_moe", "tiny_ssm", "tiny_hybrid", "tiny_encdec", "tiny_vlm",
]


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: multi-device subprocess / long-running integration tests",
    )


def make_batch(model, shape, rng: np.random.Generator):
    """Random batch matching input_specs (tokens int32 < vocab, floats ~N)."""
    specs = model.input_specs(shape)
    batch = {}
    for k, v in specs.items():
        if jnp.issubdtype(v.dtype, jnp.integer):
            batch[k] = jnp.asarray(
                rng.integers(0, model.cfg.vocab_size, size=v.shape), v.dtype
            )
        else:
            batch[k] = jnp.asarray(rng.normal(size=v.shape)).astype(v.dtype)
    return batch


@pytest.fixture(scope="session")
def tiny_corpus():
    return SyntheticCorpus(CorpusConfig(vocab_size=get_config("tiny_dense").vocab_size))


@pytest.fixture(scope="session")
def trained_tiny_dense(tiny_corpus):
    """A briefly-pretrained tiny dense LM — the 'dense teacher' for the
    pruning/EBFT integration tests (session-scoped: trained once)."""
    cfg = get_config("tiny_dense")
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt = adamw(3e-3)
    step = jax.jit(make_train_step(model.loss, opt))
    opt_state = opt.init(params)
    it = corpus_iterator(tiny_corpus, batch=32, seq_len=128, seed=1)
    for _ in range(150):
        params, opt_state, _, _ = step(
            params, opt_state, {"tokens": jnp.asarray(next(it))}, None
        )
    return model, params


@pytest.fixture(scope="session")
def tiny_calib(tiny_corpus):
    return calibration_set(tiny_corpus, 32, 128)


@pytest.fixture(scope="session")
def tiny_eval(tiny_corpus):
    return eval_set(tiny_corpus, 16, 128)
